//! [`Engine`] (per-thread PJRT CPU client) and [`ModelBundle`] (one model's
//! compiled init/train/eval executables + typed call wrappers).
//!
//! Artifact calling conventions (fixed by `python/compile/train.py`):
//! ```text
//!   init : (seed u32[2])                          -> (params f32[P],)
//!   train: (params, m, v f32[P], step i32[], x, y) ->
//!          (params', m', v', step', loss f32[], acc_count f32[])
//!   eval : (params f32[P], x, y)                  -> (loss f32[], acc_count f32[])
//! ```
//! All results come back as a single tuple (lowered with
//! `return_tuple=True`). Within an epoch the train loop keeps the model
//! state as device-side `Literal`s to avoid host conversions per step
//! (`run_steps`); host `FlatParams` are materialized only at federation
//! boundaries.

use anyhow::{anyhow, Context, Result};

use super::manifest::ModelInfo;
use crate::data::{Batch, BatchData, BatchLoader};
use crate::tensor::FlatParams;

/// A PJRT CPU client. NOT `Send` (the xla crate is `Rc`-based): create one
/// per node thread.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a PJRT CPU client for the calling thread.
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Engine { client })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact file.
    pub fn compile_hlo_file(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e}"))
    }

    /// Compile HLO text from a string (tests).
    pub fn compile_hlo_text(&self, text: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(text.as_bytes())
            .map_err(|e| anyhow!("parse hlo text: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compile hlo text: {e}"))
    }
}

/// Host-side training state (params + Adam moments + step counter).
#[derive(Clone, Debug)]
pub struct TrainState {
    /// Model parameters.
    pub params: FlatParams,
    /// Adam first-moment buffer.
    pub m: FlatParams,
    /// Adam second-moment buffer.
    pub v: FlatParams,
    /// Optimizer step counter (drives bias correction).
    pub step: i32,
}

impl TrainState {
    /// Fresh state around initialized parameters.
    pub fn new(params: FlatParams) -> TrainState {
        let n = params.len();
        TrainState { params, m: FlatParams::zeros(n), v: FlatParams::zeros(n), step: 0 }
    }

    /// Replace the parameters (after a federated aggregation), keeping the
    /// local Adam moments — matching the paper's design where only weights
    /// travel through the weight store.
    pub fn set_params(&mut self, params: FlatParams) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }
}

/// Per-step metrics from the train artifact.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    /// Mean training loss of the step's batch.
    pub loss: f32,
    /// Correct predictions in the batch (count, not rate).
    pub acc_count: f32,
    /// Predictions per batch (for normalizing acc_count).
    pub n_preds: usize,
}

/// One model's compiled executables.
pub struct ModelBundle {
    /// The manifest entry this bundle was compiled from.
    pub info: ModelInfo,
    init_exe: xla::PjRtLoadedExecutable,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

fn batch_literals(batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
    let x = match &batch.x {
        BatchData::F32(v) => xla::Literal::vec1(v).reshape(&batch.x_dims)?,
        BatchData::I32(v) => xla::Literal::vec1(v).reshape(&batch.x_dims)?,
    };
    let y = xla::Literal::vec1(&batch.y);
    Ok((x, y))
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

impl ModelBundle {
    /// Compile the model's three artifacts on this engine.
    pub fn load(engine: &Engine, info: &ModelInfo) -> Result<ModelBundle> {
        Ok(ModelBundle {
            info: info.clone(),
            init_exe: engine.compile_hlo_file(&info.init_file).context("init artifact")?,
            train_exe: engine.compile_hlo_file(&info.train_file).context("train artifact")?,
            eval_exe: engine.compile_hlo_file(&info.eval_file).context("eval artifact")?,
        })
    }

    /// Run the init artifact: deterministic parameters from a seed.
    pub fn init_params(&self, seed: u64) -> Result<FlatParams> {
        let seed_lit = xla::Literal::vec1(&[(seed >> 32) as u32, seed as u32]);
        let out = self.init_exe.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?;
        let params = out.to_tuple1()?;
        let v = params.to_vec::<f32>()?;
        anyhow::ensure!(
            v.len() == self.info.param_count,
            "init returned {} params, manifest says {}",
            v.len(),
            self.info.param_count
        );
        Ok(FlatParams(v))
    }

    /// One train step with host-side state (simple path; used by tests and
    /// single-step callers). For epochs use [`ModelBundle::run_steps`].
    pub fn train_step(&self, state: &mut TrainState, batch: &Batch) -> Result<StepMetrics> {
        let (x, y) = batch_literals(batch)?;
        let args = [
            xla::Literal::vec1(state.params.as_slice()),
            xla::Literal::vec1(state.m.as_slice()),
            xla::Literal::vec1(state.v.as_slice()),
            xla::Literal::scalar(state.step),
            x,
            y,
        ];
        let out = self.train_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        anyhow::ensure!(parts.len() == 6, "train artifact returned {} outputs", parts.len());
        let mut it = parts.into_iter();
        state.params = FlatParams(it.next().unwrap().to_vec::<f32>()?);
        state.m = FlatParams(it.next().unwrap().to_vec::<f32>()?);
        state.v = FlatParams(it.next().unwrap().to_vec::<f32>()?);
        state.step = it.next().unwrap().get_first_element::<i32>()?;
        let loss = scalar_f32(&it.next().unwrap())?;
        let acc_count = scalar_f32(&it.next().unwrap())?;
        Ok(StepMetrics { loss, acc_count, n_preds: self.info.preds_per_batch() })
    }

    /// Run `n_steps` train steps, keeping model state device-side between
    /// steps (no per-step host materialization of the P-sized vectors —
    /// the training hot path; see EXPERIMENTS.md §Perf).
    pub fn run_steps(
        &self,
        state: &mut TrainState,
        loader: &mut BatchLoader,
        n_steps: usize,
        mut on_step: impl FnMut(usize, StepMetrics),
    ) -> Result<()> {
        if n_steps == 0 {
            return Ok(());
        }
        let mut params_l = xla::Literal::vec1(state.params.as_slice());
        let mut m_l = xla::Literal::vec1(state.m.as_slice());
        let mut v_l = xla::Literal::vec1(state.v.as_slice());
        let mut step_l = xla::Literal::scalar(state.step);
        for i in 0..n_steps {
            let batch = loader.next_batch();
            let (x, y) = batch_literals(&batch)?;
            let out = self
                .train_exe
                .execute::<xla::Literal>(&[params_l, m_l, v_l, step_l, x, y])?[0][0]
                .to_literal_sync()?;
            let parts = out.to_tuple()?;
            anyhow::ensure!(parts.len() == 6, "train artifact returned {}", parts.len());
            let mut it = parts.into_iter();
            params_l = it.next().unwrap();
            m_l = it.next().unwrap();
            v_l = it.next().unwrap();
            step_l = it.next().unwrap();
            let loss = scalar_f32(&it.next().unwrap())?;
            let acc_count = scalar_f32(&it.next().unwrap())?;
            on_step(i, StepMetrics { loss, acc_count, n_preds: self.info.preds_per_batch() });
        }
        state.params = FlatParams(params_l.to_vec::<f32>()?);
        state.m = FlatParams(m_l.to_vec::<f32>()?);
        state.v = FlatParams(v_l.to_vec::<f32>()?);
        state.step = step_l.get_first_element::<i32>()?;
        Ok(())
    }

    /// Evaluate on one batch: returns (mean loss, correct count).
    pub fn eval_batch(&self, params: &FlatParams, batch: &Batch) -> Result<(f32, f32)> {
        let (x, y) = batch_literals(batch)?;
        let args = [xla::Literal::vec1(params.as_slice()), x, y];
        let out = self.eval_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (loss, acc) = out.to_tuple2()?;
        Ok((scalar_f32(&loss)?, scalar_f32(&acc)?))
    }

    /// Evaluate over a full set of batches: returns (mean loss, accuracy).
    pub fn evaluate(&self, params: &FlatParams, batches: &[Batch]) -> Result<(f64, f64)> {
        anyhow::ensure!(!batches.is_empty(), "no eval batches");
        // Keep params device-side across the eval batches.
        let params_l = xla::Literal::vec1(params.as_slice());
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut preds = 0usize;
        for b in batches {
            let (x, y) = batch_literals(b)?;
            let out = self.eval_exe.execute(&[&params_l, &x, &y])?[0][0]
                .to_literal_sync()?;
            let (loss, acc) = out.to_tuple2()?;
            loss_sum += scalar_f32(&loss)? as f64;
            correct += scalar_f32(&acc)? as f64;
            preds += self.info.preds_per_batch();
        }
        Ok((loss_sum / batches.len() as f64, correct / preds as f64))
    }
}

/// Typed alias kept for API clarity in downstream code.
pub type InitStep = ModelBundle;
/// Typed alias kept for API clarity in downstream code.
pub type TrainStep = ModelBundle;
/// Typed alias kept for API clarity in downstream code.
pub type EvalStep = ModelBundle;
