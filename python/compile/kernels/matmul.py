"""Pallas kernel: MXU-tiled matmul used by the LM's dense layers.

Grid is (M/BM, N/BN, K/BK) with fp32 accumulation into the output tile —
the classic TPU schedule: each (i, j) output tile stays resident in VMEM
while the k axis streams through, which is what a CUDA kernel would do with
threadblock tiles in shared memory (DESIGN.md §Hardware-Adaptation). Tiles
are 128-aligned for the 128x128 MXU systolic array.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # fp32 accumulate on the MXU (bf16 inputs would use preferred_element_type)
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _tiled_matmul_impl(x: jax.Array, y: jax.Array, bm: int, bn: int, bk: int):
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        y = jnp.pad(y, ((0, pk), (0, pn)))
    mm, kk, nn = m + pm, k + pk, n + pn

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mm // bm, nn // bn, kk // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _tiled_matmul_vjp(x, y, bm, bn, bk):
    return _tiled_matmul_impl(x, y, bm, bn, bk)


def _tiled_matmul_fwd(x, y, bm, bn, bk):
    return _tiled_matmul_impl(x, y, bm, bn, bk), (x, y)


def _tiled_matmul_bwd(bm, bn, bk, res, g):
    # dX = g @ Y^T, dY = X^T @ g — both through the same MXU-tiled kernel so
    # the backward pass of the lowered train artifact also exercises L1.
    x, y = res
    dx = _tiled_matmul_impl(g, y.T, bm, bn, bk)
    dy = _tiled_matmul_impl(x.T, g, bm, bn, bk)
    return dx, dy


_tiled_matmul_vjp.defvjp(_tiled_matmul_fwd, _tiled_matmul_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def tiled_matmul(x: jax.Array, y: jax.Array, bm: int = BM, bn: int = BN, bk: int = BK):
    """x @ y with MXU-shaped tiling; shapes may be un-padded; differentiable
    via a custom VJP whose backward matmuls reuse the same kernel.

    Args:
      x: f32[M, K]; y: f32[K, N].

    Returns:
      f32[M, N].
    """
    return _tiled_matmul_vjp(x, y, bm, bn, bk)
