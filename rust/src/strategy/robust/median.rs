//! [`Median`] — coordinate-wise median aggregation.

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

use super::super::{Contribution, Strategy};
use super::{by_node, per_coordinate};

/// Coordinate-wise median: each output coordinate is the median of that
/// coordinate across all clients (even counts average the two central
/// values). Breakdown point ⌊(n−1)/2⌋ — up to that many clients can push
/// arbitrary vectors without moving a single output coordinate outside
/// the honest range.
#[derive(Clone, Copy, Debug, Default)]
pub struct Median;

impl Median {
    /// Stateless constructor (parity with the other strategies).
    pub fn new() -> Self {
        Median
    }
}

/// Median of a column already sorted by the `f32` total order.
pub(crate) fn sorted_median(col: &[f32]) -> f32 {
    let m = col.len();
    if m % 2 == 1 {
        col[m / 2]
    } else {
        let lo = col[m / 2 - 1];
        let hi = col[m / 2];
        lo + (hi - lo) * 0.5
    }
}

impl Strategy for Median {
    fn name(&self) -> &'static str {
        "median"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let sorted = by_node(contribs);
        Some(per_coordinate(&sorted, pool, sorted_median))
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn odd_count_picks_middle() {
        let cs = [
            contrib(0, 100, true, &[1.0, 10.0]),
            contrib(1, 100, false, &[2.0, -5.0]),
            contrib(2, 100, false, &[1000.0, 0.0]),
        ];
        let out = Median::new().aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![2.0, 0.0]);
    }

    #[test]
    fn even_count_averages_central_pair() {
        let cs = [
            contrib(0, 100, true, &[0.0]),
            contrib(1, 100, false, &[1.0]),
            contrib(2, 100, false, &[3.0]),
            contrib(3, 100, false, &[100.0]),
        ];
        let out = Median::new().aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![2.0]);
    }

    #[test]
    fn ignores_example_counts() {
        // a heavy adversary cannot buy weight with a large n_examples
        let cs = [
            contrib(0, 1, true, &[1.0]),
            contrib(1, 1, false, &[1.0]),
            contrib(2, 1_000_000, false, &[1e9]),
        ];
        let out = Median::new().aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![1.0]);
    }
}
