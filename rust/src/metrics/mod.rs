//! Metrics: summary statistics (mean ± 95% CI, as the paper's tables
//! report), run logging (CSV/JSONL — the W&B substitute), per-node
//! timelines used to regenerate the Figure-1 straggler-idle picture,
//! and per-node weight-store traffic accounting ([`TrafficMeter`]).

pub mod logger;
pub mod stats;
pub mod timeline;
pub mod traffic;

pub use logger::{EventField, RunLogger};
pub use stats::Summary;
pub use timeline::{SpanKind, Timeline};
pub use traffic::TrafficMeter;
