"""Layer-2 model zoo: pure-jnp models with flat-parameter train/eval steps.

Three families mirroring the paper's experiments (§4):
  * ``mnist_cnn``    — 2x conv + maxpool + ReLU + dense   (paper §4.2)
  * ``cifar_resnet`` — ResNet-lite with residual stages   (paper §4.3)
  * ``lm_transformer`` — pre-LN GPT (Pythia-style)        (paper §4.4)

Every model exposes:
  init(rng) -> params pytree
  apply(params, x, train) -> logits
and `registry.get(name)` returns a ModelSpec with static shape/config info
used by aot.py to build artifacts and by the manifest consumed in rust.
"""

from .registry import MODELS, ModelSpec, get_model

__all__ = ["MODELS", "ModelSpec", "get_model"]
