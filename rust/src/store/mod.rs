//! The **weight store** — the shared blob namespace that replaces the
//! central federation server (the paper's core architectural move).
//!
//! "the weight store is intended to be any remote folder that is
//! accessible by the client machine, for example a bucket/blob location on
//! a cloud service provider" (§3). Clients *push* their weights after an
//! epoch, *pull* the latest weights of their peers, and aggregate
//! **client-side**; a cheap [`WeightStore::state_hash`] lets a client detect
//! "if the remote server has changed state" without downloading anything
//! (Algorithm 1).
//!
//! Change detection is event-driven: every mutation advances a monotone
//! [`WeightStore::version`] counter, and [`WeightStore::wait_for_change`]
//! blocks until the counter moves past a caller-held token (condition
//! notification in the in-process stores, backoff LIST-polling in
//! [`FsStore`]) — so protocol barriers park on a notification instead of
//! busy-polling the store (see `crate::protocol`). All waits and
//! injected delays run in a [`crate::time::Clock`]'s time domain: build
//! a store `with_clock` on a [`crate::time::VirtualClock`] and every
//! park/sleep consumes *simulated* time (instant in real time), which is
//! what lets timing experiments run at CPU speed.
//!
//! Implementations:
//! * [`MemoryStore`]  — in-process, for simulation and tests.
//! * [`ShardedStore`] — in-process, partitioned by `node_id` across
//!   independently locked shards; the scalable choice for 8+ nodes and
//!   for concurrent sweep trials.
//! * [`FsStore`]      — a directory of blob files; the S3Folder analogue,
//!   usable by genuinely separate OS processes.
//! * [`LatencyStore`] — wraps any store with configurable latency/jitter
//!   (simulated S3 RTT).
//! * [`CachedStore`]  — read-through cache keyed by the state hash.
//! * [`FaultStore`]   — wraps any store with seeded error injection and
//!   scheduled outage windows (pure in `(seed, simulated-time)`).
//! * [`RetryStore`]   — retrying client wrapper: exponential backoff with
//!   seeded deterministic jitter on the experiment clock, per-op deadline
//!   budgets, and a transient-vs-permanent [`StoreError`] taxonomy.
//! * [`AdversaryStore`] — wraps any store and rewrites the *content* of
//!   selected pushes per an [`AdversarySpec`] (Byzantine noise, scaling,
//!   sign-flips, stale replays) — the attack layer the robust
//!   aggregators in `crate::strategy::robust` defend against.
//!
//! Wrappers compose: `FaultStore<CachedStore<ShardedStore>>` is a valid
//! stack (and is exercised by this module's composition tests).

mod adversary;
mod cached;
mod fault;
mod fs;
mod latency;
mod memory;
mod retry;
mod sharded;

pub use adversary::{AdversaryKind, AdversarySpec, AdversaryStore, BYZANTINE_SIGMA};
pub use cached::CachedStore;
pub use fault::{FaultModel, FaultStore, OutageWindow};
pub use fs::FsStore;
pub use latency::{LatencyConfig, LatencyStore};
pub use memory::MemoryStore;
pub use retry::{RetryPolicy, RetryStats, RetryStore};
pub use sharded::{ShardedStore, DEFAULT_SHARDS};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::tensor::FlatParams;
use crate::time::{Clock, Condition, RealClock};

/// Whether a failed store operation is worth retrying.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreErrorKind {
    /// The operation may succeed if repeated (injected fault, outage
    /// window, flaky I/O) — [`RetryStore`] retries these with backoff.
    Transient,
    /// Retrying cannot help (bad arguments, programming error) — the
    /// error propagates immediately.
    Permanent,
}

/// Typed store failure threaded through `anyhow` context chains so the
/// retry layer can tell a flaky operation from a doomed one. Producers
/// attach one via [`StoreError::transient`] / [`StoreError::permanent`];
/// consumers classify any `anyhow::Error` with [`StoreError::classify`].
/// Errors carrying no `StoreError` anywhere in their chain classify as
/// [`StoreErrorKind::Permanent`] — unknown failures are not retried.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreError {
    /// Retryability of the failed operation.
    pub kind: StoreErrorKind,
    /// The store operation that failed (`"push"`, `"state_hash"`, …).
    pub op: &'static str,
    /// Human-readable failure detail.
    pub detail: String,
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match self.kind {
            StoreErrorKind::Transient => "transient",
            StoreErrorKind::Permanent => "permanent",
        };
        write!(f, "{} store error during {}: {}", kind, self.op, self.detail)
    }
}

impl std::error::Error for StoreError {}

impl StoreError {
    /// A retryable failure of `op` as an `anyhow::Error`.
    pub fn transient(op: &'static str, detail: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(StoreError {
            kind: StoreErrorKind::Transient,
            op,
            detail: detail.into(),
        })
    }

    /// A non-retryable failure of `op` as an `anyhow::Error`.
    pub fn permanent(op: &'static str, detail: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(StoreError {
            kind: StoreErrorKind::Permanent,
            op,
            detail: detail.into(),
        })
    }

    /// Classify an error by the first [`StoreError`] in its source chain;
    /// errors with no typed store failure anywhere are `Permanent`.
    pub fn classify(err: &anyhow::Error) -> StoreErrorKind {
        err.chain()
            .find_map(|e| e.downcast_ref::<StoreError>())
            .map(|s| s.kind)
            .unwrap_or(StoreErrorKind::Permanent)
    }
}

/// One deposited weight entry.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    /// Id of the node that deposited this entry.
    pub node_id: usize,
    /// Sync protocol: the federation round. Async: the node's epoch count.
    pub round: u64,
    /// The depositing node's local epoch counter.
    pub epoch: u64,
    /// Examples this client trained on (the FedAvg weight numerator n_k).
    pub n_examples: u64,
    /// Store-assigned monotonically increasing sequence number.
    pub seq: u64,
    /// Simulated wire size of this entry in bytes: the encoded blob,
    /// header included (see [`crate::tensor::codec`]). Raw entries cost
    /// [`crate::tensor::codec::raw_wire_bytes`]; codec-encoded entries
    /// carry their actual compressed size. [`LatencyStore`] charges
    /// bandwidth on this, and the protocol layer's
    /// [`crate::metrics::TrafficMeter`] accounts it per node.
    pub wire_bytes: u64,
    /// The deposited flat weight vector (shared, not copied, in-process).
    pub params: std::sync::Arc<FlatParams>,
}

/// Shared blob namespace for serverless federation. All methods are
/// thread-safe; `&self` receivers allow `Arc<dyn WeightStore>` sharing
/// across node threads.
pub trait WeightStore: Send + Sync {
    /// Deposit this node's weights. Returns the assigned sequence number.
    fn push(&self, entry: PushRequest) -> Result<u64>;

    /// Latest entry per node (the async protocol's pull set ω).
    fn latest_per_node(&self) -> Result<Vec<WeightEntry>>;

    /// All entries deposited for a specific sync round.
    ///
    /// Retention contract: the in-process backends keep *every*
    /// deposited entry until [`WeightStore::clear`], so this doubles as
    /// the post-hoc round archive behind the divergence analytics
    /// ([`crate::trace::compute_divergence`]) — re-pushed rounds return
    /// every revision and the analyzer keeps each node's latest.
    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>>;

    /// Cheap change-detection hash over (node, seq) pairs. A client skips
    /// aggregation when this hasn't moved since its last pull (Algorithm 1:
    /// "performs a check to see if the remote server has changed state").
    fn state_hash(&self) -> Result<u64>;

    /// Latest entry for a single node (the gossip protocol's per-peer
    /// pull); `None` if that node never deposited.
    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>>;

    /// Monotone change counter: advances on every mutation (`push` or
    /// `clear`). Tokens are only comparable against the same store handle
    /// — wrappers forward to their inner store, and [`FsStore`] derives a
    /// handle-local counter from directory state.
    fn version(&self) -> Result<u64>;

    /// Block until [`WeightStore::version`] exceeds `since` or `timeout`
    /// elapses; returns the version observed at wake-up (a return value
    /// equal to `since` is a clean timeout). In-process stores park on a
    /// Condvar and wake on the next mutation; [`FsStore`] polls the
    /// directory listing with exponential backoff (the bucket-watching
    /// analogue). Spurious early returns are allowed — callers re-check
    /// their predicate in a loop.
    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64>;

    /// Number of push operations performed (for metrics/backpressure).
    fn push_count(&self) -> u64;

    /// Remove all entries (between trials).
    fn clear(&self) -> Result<()>;

    /// Conditional put (compare-and-swap): deposit `req` only if the
    /// store's [`WeightStore::version`] still equals `expected`. Returns
    /// `Ok(Some(seq))` when the put landed, `Ok(None)` when the store
    /// moved past `expected` (the caller's read is stale — re-pull and
    /// decide again), and `Err` only for operation failures. This is how
    /// a recovering node (and any future multi-process writer) deposits
    /// state without clobbering anything newer than what it last read.
    ///
    /// Backends make the check-then-put atomic with respect to their own
    /// mutation path; wrappers forward to the inner store so the
    /// linearization point is always the base store's. This default
    /// implementation is a *non-atomic* check-then-push for simple test
    /// doubles only — every real backend and wrapper overrides it.
    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        if self.version()? != expected {
            return Ok(None);
        }
        self.push(req).map(Some)
    }
}

/// Clock-aware monotone change counter shared by the in-process stores:
/// `bump` after a mutation is visible, and waiters parked in
/// [`ChangeNotifier::wait_for_change`] wake immediately. Timeouts are
/// measured in the owning [`Clock`]'s time domain, so a store built with
/// a [`crate::time::VirtualClock`] parks in *simulated* time (the wait
/// completes instantly in real time once every node is blocked).
pub(crate) struct ChangeNotifier {
    version: AtomicU64,
    clock: Arc<dyn Clock>,
    cond: Arc<dyn Condition>,
}

impl Default for ChangeNotifier {
    fn default() -> Self {
        ChangeNotifier::new(RealClock::shared())
    }
}

impl ChangeNotifier {
    /// A notifier parking in `clock`'s time domain.
    pub(crate) fn new(clock: Arc<dyn Clock>) -> ChangeNotifier {
        let cond = clock.condition();
        ChangeNotifier { version: AtomicU64::new(0), clock, cond }
    }

    /// Advance the counter and wake every parked waiter. Call only after
    /// the mutation is visible to readers.
    pub(crate) fn bump(&self) {
        // Version first, then notify: a woken waiter must observe the
        // new counter (the condition's epoch/notify pairing makes the
        // check-then-wait race in `wait_for_change` benign).
        self.version.fetch_add(1, Ordering::SeqCst);
        self.cond.notify_all();
    }

    /// Current counter value.
    pub(crate) fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    /// Park until the counter exceeds `since` or `timeout` of clock time
    /// elapses; returns the counter observed at wake-up.
    pub(crate) fn wait_for_change(&self, since: u64, timeout: Duration) -> u64 {
        let start = self.clock.now();
        loop {
            // Epoch token *before* the predicate check: a bump landing in
            // between turns the wait into an immediate return instead of
            // a lost wake-up.
            let tok = self.cond.epoch();
            let v = self.version();
            if v > since {
                return v;
            }
            let elapsed = self.clock.now().saturating_sub(start);
            if elapsed >= timeout {
                return v;
            }
            self.cond.wait_past(tok, timeout - elapsed);
        }
    }
}

/// Append-only entry log plus a per-node latest-entry index, kept
/// consistent under the owner's lock — the shared storage core of
/// [`MemoryStore`] (one behind its lock) and [`ShardedStore`] (one per
/// shard; a node's entries all land in one shard, so its latest entry
/// does too). The index makes `latest_per_node` / `latest_for_node`
/// O(nodes) instead of an O(log-length) scan; entries share params via
/// `Arc`, so the index clone is cheap.
#[derive(Default)]
pub(crate) struct EntryLog {
    /// Every entry ever pushed (round queries, state hash).
    pub(crate) log: Vec<WeightEntry>,
    /// Latest entry per node, maintained on push.
    pub(crate) latest: std::collections::BTreeMap<usize, WeightEntry>,
}

impl EntryLog {
    /// Append an entry and update the latest index. The index update is
    /// conditional on seq: seqs are assigned *before* the owner's lock,
    /// so two pushes from one node can land out of order and the index
    /// must keep the max — exactly like the scan it replaces
    /// (regression-tested by `store_tests::latest_index_matches_scan`).
    pub(crate) fn push(&mut self, entry: WeightEntry) {
        match self.latest.get(&entry.node_id) {
            Some(prev) if prev.seq >= entry.seq => {}
            _ => {
                self.latest.insert(entry.node_id, entry.clone());
            }
        }
        self.log.push(entry);
    }

    /// Drop every entry and the index (between trials).
    pub(crate) fn clear(&mut self) {
        self.log.clear();
        self.latest.clear();
    }
}

/// Arguments to [`WeightStore::push`].
#[derive(Clone, Debug)]
pub struct PushRequest {
    /// Id of the pushing node.
    pub node_id: usize,
    /// Sync protocol: the federation round. Async: the node's epoch count.
    pub round: u64,
    /// The pushing node's local epoch counter.
    pub epoch: u64,
    /// Examples this client trained on (the FedAvg weight numerator n_k).
    pub n_examples: u64,
    /// Simulated wire size of the encoded entry (blob header included);
    /// copied onto the stored [`WeightEntry::wire_bytes`]. Use
    /// [`PushRequest::raw`] when pushing uncompressed params.
    pub wire_bytes: u64,
    /// The flat weight vector to deposit.
    pub params: std::sync::Arc<FlatParams>,
}

impl PushRequest {
    /// A push of uncompressed params: `wire_bytes` is the raw v1 blob
    /// size ([`crate::tensor::codec::raw_wire_bytes`]).
    pub fn raw(
        node_id: usize,
        round: u64,
        epoch: u64,
        n_examples: u64,
        params: std::sync::Arc<FlatParams>,
    ) -> PushRequest {
        let wire_bytes = crate::tensor::codec::raw_wire_bytes(params.len());
        PushRequest { node_id, round, epoch, n_examples, wire_bytes, params }
    }
}

/// `Arc<dyn WeightStore>` is itself a store, so wrappers generic over a
/// concrete store type (`LatencyStore<S>`, `CachedStore<S>`, …) can stack
/// on top of dynamically-chosen inner stores.
impl WeightStore for std::sync::Arc<dyn WeightStore> {
    fn push(&self, entry: PushRequest) -> Result<u64> {
        (**self).push(entry)
    }
    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        (**self).latest_per_node()
    }
    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        (**self).entries_for_round(round)
    }
    fn state_hash(&self) -> Result<u64> {
        (**self).state_hash()
    }
    fn latest_for_node(&self, node_id: usize) -> Result<Option<WeightEntry>> {
        (**self).latest_for_node(node_id)
    }
    fn version(&self) -> Result<u64> {
        (**self).version()
    }
    fn wait_for_change(&self, since: u64, timeout: Duration) -> Result<u64> {
        (**self).wait_for_change(since, timeout)
    }
    fn push_count(&self) -> u64 {
        (**self).push_count()
    }
    fn clear(&self) -> Result<()> {
        (**self).clear()
    }
    fn push_if_version(&self, req: PushRequest, expected: u64) -> Result<Option<u64>> {
        // must forward explicitly: the trait default would re-derive a
        // non-atomic check-then-push instead of the inner store's CAS
        (**self).push_if_version(req, expected)
    }
}

#[cfg(test)]
pub(crate) mod store_tests {
    //! Conformance suite run against every store implementation.
    use std::sync::Arc;

    use super::*;

    pub fn push_req(node: usize, round: u64, val: f32) -> PushRequest {
        let params = Arc::new(FlatParams(vec![val; 8]));
        PushRequest::raw(node, round, round, 100 + node as u64, params)
    }

    pub fn conformance(store: &dyn WeightStore) {
        // empty
        assert!(store.latest_per_node().unwrap().is_empty());
        let h0 = store.state_hash().unwrap();

        // push two nodes
        store.push(push_req(0, 0, 1.0)).unwrap();
        let h1 = store.state_hash().unwrap();
        assert_ne!(h0, h1, "state hash must change on push");
        store.push(push_req(1, 0, 2.0)).unwrap();

        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 2);
        let r0 = store.entries_for_round(0).unwrap();
        assert_eq!(r0.len(), 2);
        assert!(store.entries_for_round(1).unwrap().is_empty());

        // node 0 pushes a newer entry: latest_per_node must pick it
        store.push(push_req(0, 1, 3.0)).unwrap();
        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 2);
        let e0 = latest.iter().find(|e| e.node_id == 0).unwrap();
        assert_eq!(e0.round, 1);
        assert_eq!(e0.params.0[0], 3.0);
        // seq strictly increases
        let e1 = latest.iter().find(|e| e.node_id == 1).unwrap();
        assert!(e0.seq > e1.seq);

        // payload integrity
        assert_eq!(e1.params.0, vec![2.0; 8]);
        assert_eq!(e1.n_examples, 101);
        // wire accounting survives the store round-trip
        assert_eq!(e1.wire_bytes, crate::tensor::codec::raw_wire_bytes(8));

        // single-node pull (the gossip protocol's per-peer read)
        let s0 = store.latest_for_node(0).unwrap().unwrap();
        assert_eq!(s0.round, 1);
        assert_eq!(s0.params.0[0], 3.0);
        assert!(store.latest_for_node(9).unwrap().is_none());

        // clear
        store.clear().unwrap();
        assert!(store.latest_per_node().unwrap().is_empty());
        assert!(store.entries_for_round(0).unwrap().is_empty());
        assert!(store.latest_for_node(0).unwrap().is_none());
    }

    /// Conformance for the change-subscription API: `version` advances on
    /// every mutation, `wait_for_change` wakes on a concurrent push and
    /// times out cleanly on an unchanged store.
    pub fn subscription(store: Arc<dyn WeightStore>) {
        use std::time::{Duration, Instant};

        let v0 = store.version().unwrap();
        store.push(push_req(0, 0, 1.0)).unwrap();
        let v1 = store.version().unwrap();
        assert!(v1 > v0, "push must advance the version");

        // unchanged store: block until the timeout, return the old token
        let t = Instant::now();
        let v = store.wait_for_change(v1, Duration::from_millis(40)).unwrap();
        assert!(
            t.elapsed() >= Duration::from_millis(30),
            "unchanged store must block until the timeout"
        );
        assert_eq!(v, v1, "clean timeout returns the unchanged version");

        // wake on a concurrent push from another thread
        let pusher = {
            let s = Arc::clone(&store);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                s.push(push_req(1, 0, 2.0)).unwrap();
            })
        };
        let t = Instant::now();
        let v2 = store.wait_for_change(v1, Duration::from_secs(20)).unwrap();
        assert!(v2 > v1, "waiter must observe the concurrent push");
        assert!(
            t.elapsed() < Duration::from_secs(10),
            "waiter must wake on the push, not ride out the timeout"
        );
        pusher.join().unwrap();

        // clear is a mutation too
        let vc = store.version().unwrap();
        store.clear().unwrap();
        assert!(store.version().unwrap() > vc, "clear must advance the version");
    }

    /// Conformance plus the 8-thread stress test, the subscription
    /// suite, and the conditional-put suite for a wrapper stack built by
    /// `make_store` (fresh store per phase, since `conformance` ends
    /// with a `clear` and `concurrent_pushes` counts pushes).
    pub fn stack_conformance<S, F>(make_store: F)
    where
        S: WeightStore + 'static,
        F: Fn() -> S,
    {
        conformance(&make_store());
        concurrent_pushes(Arc::new(make_store()));
        subscription(Arc::new(make_store()));
        cas_conformance(&make_store());
        cas_lost_update(Arc::new(make_store()));
    }

    /// Conformance for [`WeightStore::push_if_version`]: a put with the
    /// current version lands; a put with a stale version is refused
    /// without writing anything; a refreshed token works again.
    pub fn cas_conformance(store: &dyn WeightStore) {
        let v0 = store.version().unwrap();
        let seq = store.push_if_version(push_req(0, 0, 1.0), v0).unwrap();
        assert!(seq.is_some(), "CAS with the current version must land");
        let v1 = store.version().unwrap();
        assert!(v1 > v0, "a successful CAS is a mutation and must advance the version");

        // stale token: refused, and nothing is written
        let pushes = store.push_count();
        let refused = store.push_if_version(push_req(1, 0, 9.0), v0).unwrap();
        assert!(refused.is_none(), "CAS with a stale version must be refused");
        assert_eq!(store.push_count(), pushes, "a refused CAS must not push");
        assert!(
            store.latest_for_node(1).unwrap().is_none(),
            "a refused CAS must leave no entry behind"
        );
        assert_eq!(store.version().unwrap(), v1, "a refused CAS is not a mutation");

        // a re-read token works again
        let seq = store.push_if_version(push_req(1, 0, 2.0), v1).unwrap();
        assert!(seq.is_some(), "CAS with a refreshed version must land");
        assert_eq!(store.latest_for_node(1).unwrap().unwrap().params.0[0], 2.0);
    }

    /// Lost-update regression: N writers race `push_if_version` against
    /// the same version token — exactly one may win, so concurrent
    /// recovery pushes can never silently clobber each other.
    pub fn cas_lost_update(store: Arc<dyn WeightStore>) {
        store.push(push_req(0, 0, 0.0)).unwrap();
        let token = store.version().unwrap();
        let start = Arc::new(std::sync::Barrier::new(6));
        let threads: Vec<_> = (1..=6)
            .map(|node| {
                let s = Arc::clone(&store);
                let go = Arc::clone(&start);
                std::thread::spawn(move || {
                    go.wait();
                    s.push_if_version(push_req(node, 1, node as f32), token)
                        .unwrap()
                        .is_some()
                })
            })
            .collect();
        let wins = threads.into_iter().filter(|t| t.join().unwrap()).count();
        assert_eq!(wins, 1, "exactly one racing CAS writer may win");
    }

    /// Regression for the maintained per-node latest index: after a
    /// ragged multi-round push schedule, `latest_per_node` /
    /// `latest_for_node` must agree with a full scan reconstructed from
    /// the round queries, and `push_count` must stay exact.
    pub fn latest_index_matches_scan(store: &dyn WeightStore) {
        let mut expected: std::collections::BTreeMap<usize, (u64, f32)> = Default::default();
        let mut pushes = 0u64;
        for round in 0..7u64 {
            for node in 0..5usize {
                if (node + round as usize) % 3 == 0 {
                    continue; // ragged participation, like async reality
                }
                let val = (node * 100 + round as usize) as f32;
                let seq = store.push(push_req(node, round, val)).unwrap();
                expected.insert(node, (seq, val));
                pushes += 1;
            }
        }
        assert_eq!(store.push_count(), pushes, "push_count must stay exact");

        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), expected.len());
        for e in &latest {
            let (seq, val) = expected[&e.node_id];
            assert_eq!(e.seq, seq, "node {} latest seq", e.node_id);
            assert_eq!(e.params.0[0], val, "node {} latest payload", e.node_id);
            let single = store.latest_for_node(e.node_id).unwrap().unwrap();
            assert_eq!(single.seq, seq, "latest_for_node must agree");
        }

        // the index must equal a scan rebuilt from the full log
        let mut scan: std::collections::BTreeMap<usize, WeightEntry> = Default::default();
        for round in 0..7u64 {
            for e in store.entries_for_round(round).unwrap() {
                match scan.get(&e.node_id) {
                    Some(prev) if prev.seq >= e.seq => {}
                    _ => {
                        scan.insert(e.node_id, e);
                    }
                }
            }
        }
        let scanned: Vec<WeightEntry> = scan.into_values().collect();
        assert_eq!(latest.len(), scanned.len());
        for (a, b) in latest.iter().zip(&scanned) {
            assert_eq!((a.node_id, a.seq), (b.node_id, b.seq), "index diverged from scan");
        }
    }

    pub fn concurrent_pushes(store: Arc<dyn WeightStore>) {
        let threads: Vec<_> = (0..8)
            .map(|node| {
                let s = Arc::clone(&store);
                std::thread::spawn(move || {
                    for round in 0..20 {
                        s.push(push_req(node, round, node as f32)).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 8);
        for e in &latest {
            assert_eq!(e.round, 19, "node {} latest round", e.node_id);
            assert_eq!(e.params.0[0], e.node_id as f32);
        }
        assert_eq!(store.push_count(), 160);
    }
}

#[cfg(test)]
mod stack_tests {
    //! Wrapper-stack compositions: the conformance suite must hold for any
    //! wrapper stacked on any backend, not just for each layer in
    //! isolation (a caching bug, say, could only surface over a sharded
    //! inner store whose read order differs from the push order).

    use std::sync::Arc;

    use super::store_tests::stack_conformance;
    use super::*;

    #[test]
    fn cached_over_sharded() {
        stack_conformance(|| CachedStore::new(ShardedStore::default()));
    }

    #[test]
    fn fault_over_sharded_p_zero_is_transparent() {
        stack_conformance(|| FaultStore::new(ShardedStore::default(), 0.0, 1));
    }

    #[test]
    fn fault_over_cached_over_sharded() {
        stack_conformance(|| FaultStore::new(CachedStore::new(ShardedStore::new(3)), 0.0, 7));
    }

    #[test]
    fn cached_over_memory() {
        stack_conformance(|| CachedStore::new(MemoryStore::new()));
    }

    #[test]
    fn latency_over_sharded_zero_cost() {
        stack_conformance(|| {
            LatencyStore::new(ShardedStore::default(), LatencyConfig::none(), 11)
        });
    }

    #[test]
    fn cached_pulls_hit_on_unchanged_sharded_store() {
        // The cache keys on the *merged* sharded hash. The foreign push
        // goes through a second handle on the same inner store, so only
        // the hash change can reveal it — a ShardedStore::state_hash
        // that skipped a shard would serve stale weights here.
        let inner: Arc<dyn WeightStore> = Arc::new(ShardedStore::new(4));
        let s = CachedStore::new(Arc::clone(&inner));
        s.push(store_tests::push_req(0, 0, 1.0)).unwrap();
        let _ = s.latest_per_node().unwrap();
        let _ = s.latest_per_node().unwrap();
        let (hits, misses) = s.stats();
        assert_eq!((hits, misses), (1, 1));
        // foreign push into a *different shard*, bypassing the cache
        inner.push(store_tests::push_req(3, 0, 2.0)).unwrap();
        let entries = s.latest_per_node().unwrap();
        assert_eq!(entries.len(), 2, "merged hash must reveal the foreign shard's push");
        let (_, misses) = s.stats();
        assert_eq!(misses, 2, "push into another shard must invalidate");
    }
}
