"""Decoder-only transformer LM (paper §4.4 used Pythia-14M on WikiText).

Pre-LN GPT architecture (the Pythia family's layout): token + learned
positional embeddings, L blocks of causal MHA + GELU MLP, final LayerNorm,
untied unembedding. Size is configurable; `lm` (~1.9M) keeps federated
trials fast on CPU, `lm14m` matches Pythia-14M's parameter budget for the
end-to-end example. Dense projections route through the L1 Pallas tiled
matmul when enabled (artifact builds).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import common as c


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 256  # byte-level tokenizer (rust side)
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    mlp_mult: int = 4


CONFIGS = {
    "lm": LMConfig(),
    "lm_medium": LMConfig(d_model=256, n_layers=4, n_heads=8, seq_len=128),
    "lm14m": LMConfig(d_model=512, n_layers=6, n_heads=8, seq_len=128),
}


def _block_init(key, cfg: LMConfig):
    d, h = cfg.d_model, cfg.mlp_mult * cfg.d_model
    k = jax.random.split(key, 6)
    return {
        "ln1": c.layernorm_init(d),
        "attn": {
            "wqkv": c.dense_init(k[0], d, 3 * d),
            "wo": c.dense_init(k[1], d, d),
        },
        "ln2": c.layernorm_init(d),
        "mlp": {
            "w1": c.dense_init(k[2], d, h),
            "w2": c.dense_init(k[3], h, d),
        },
    }


def _attn(p, x, cfg: LMConfig):
    b, t, d = x.shape
    nh, hd = cfg.n_heads, d // cfg.n_heads
    qkv = c.dense(p["wqkv"], x)  # [B, T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return c.dense(p["wo"], out)


def _block(p, x, cfg: LMConfig):
    x = x + _attn(p["attn"], c.layernorm(p["ln1"], x), cfg)
    h = c.dense(p["mlp"]["w1"], c.layernorm(p["ln2"], x))
    h = jax.nn.gelu(h)
    return x + c.dense(p["mlp"]["w2"], h)


def make_init(cfg: LMConfig):
    def init(key):
        keys = jax.random.split(key, cfg.n_layers + 3)
        params = {
            "wte": c.normal(keys[0], (cfg.vocab, cfg.d_model)),
            "wpe": c.normal(keys[1], (cfg.seq_len, cfg.d_model)),
            "ln_f": c.layernorm_init(cfg.d_model),
            "unembed": c.dense_init(keys[2], cfg.d_model, cfg.vocab, bias=False),
        }
        for i in range(cfg.n_layers):
            params[f"block{i}"] = _block_init(keys[i + 3], cfg)
        return params

    return init


def make_apply(cfg: LMConfig):
    def apply(params, tokens, train=False):
        """tokens: i32[B, T] -> logits f32[B, T, V]."""
        del train
        b, t = tokens.shape
        x = params["wte"][tokens] + params["wpe"][:t]
        for i in range(cfg.n_layers):
            x = _block(params[f"block{i}"], x, cfg)
        x = c.layernorm(params["ln_f"], x)
        return c.dense(params["unembed"], x)

    return apply


def make_loss(cfg: LMConfig):
    apply = make_apply(cfg)

    def loss_and_metrics(params, batch, train=False):
        """batch = (tokens i32[B, T+1], _ignored). Next-token prediction:
        loss over positions 0..T-1 predicting 1..T; returns (mean loss,
        count of correct next-token predictions)."""
        tokens, _ = batch
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits = apply(params, inp, train)
        return c.softmax_xent(logits, tgt), c.accuracy_count(logits, tgt)

    return loss_and_metrics
