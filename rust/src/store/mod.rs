//! The **weight store** — the shared blob namespace that replaces the
//! central federation server (the paper's core architectural move).
//!
//! "the weight store is intended to be any remote folder that is
//! accessible by the client machine, for example a bucket/blob location on
//! a cloud service provider" (§3). Clients *push* their weights after an
//! epoch, *pull* the latest weights of their peers, and aggregate
//! **client-side**; a cheap [`WeightStore::state_hash`] lets a client detect
//! "if the remote server has changed state" without downloading anything
//! (Algorithm 1).
//!
//! Implementations:
//! * [`MemoryStore`] — in-process, for simulation and tests.
//! * [`FsStore`]     — a directory of blob files; the S3Folder analogue,
//!   usable by genuinely separate OS processes.
//! * [`LatencyStore`] — wraps any store with configurable latency/jitter
//!   (simulated S3 RTT).
//! * [`FaultStore`]  — wraps any store with seeded error injection.

mod cached;
mod fault;
mod fs;
mod latency;
mod memory;

pub use cached::CachedStore;
pub use fault::FaultStore;
pub use fs::FsStore;
pub use latency::{LatencyConfig, LatencyStore};
pub use memory::MemoryStore;

use anyhow::Result;

use crate::tensor::FlatParams;

/// One deposited weight entry.
#[derive(Clone, Debug)]
pub struct WeightEntry {
    pub node_id: usize,
    /// Sync protocol: the federation round. Async: the node's epoch count.
    pub round: u64,
    pub epoch: u64,
    /// Examples this client trained on (the FedAvg weight numerator n_k).
    pub n_examples: u64,
    /// Store-assigned monotonically increasing sequence number.
    pub seq: u64,
    pub params: std::sync::Arc<FlatParams>,
}

/// Shared blob namespace for serverless federation. All methods are
/// thread-safe; `&self` receivers allow `Arc<dyn WeightStore>` sharing
/// across node threads.
pub trait WeightStore: Send + Sync {
    /// Deposit this node's weights. Returns the assigned sequence number.
    fn push(&self, entry: PushRequest) -> Result<u64>;

    /// Latest entry per node (the async protocol's pull set ω).
    fn latest_per_node(&self) -> Result<Vec<WeightEntry>>;

    /// All entries deposited for a specific sync round.
    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>>;

    /// Cheap change-detection hash over (node, seq) pairs. A client skips
    /// aggregation when this hasn't moved since its last pull (Algorithm 1:
    /// "performs a check to see if the remote server has changed state").
    fn state_hash(&self) -> Result<u64>;

    /// Number of push operations performed (for metrics/backpressure).
    fn push_count(&self) -> u64;

    /// Remove all entries (between trials).
    fn clear(&self) -> Result<()>;
}

/// Arguments to [`WeightStore::push`].
#[derive(Clone, Debug)]
pub struct PushRequest {
    pub node_id: usize,
    pub round: u64,
    pub epoch: u64,
    pub n_examples: u64,
    pub params: std::sync::Arc<FlatParams>,
}

/// `Arc<dyn WeightStore>` is itself a store, so wrappers generic over a
/// concrete store type (`LatencyStore<S>`, `CachedStore<S>`, …) can stack
/// on top of dynamically-chosen inner stores.
impl WeightStore for std::sync::Arc<dyn WeightStore> {
    fn push(&self, entry: PushRequest) -> Result<u64> {
        (**self).push(entry)
    }
    fn latest_per_node(&self) -> Result<Vec<WeightEntry>> {
        (**self).latest_per_node()
    }
    fn entries_for_round(&self, round: u64) -> Result<Vec<WeightEntry>> {
        (**self).entries_for_round(round)
    }
    fn state_hash(&self) -> Result<u64> {
        (**self).state_hash()
    }
    fn push_count(&self) -> u64 {
        (**self).push_count()
    }
    fn clear(&self) -> Result<()> {
        (**self).clear()
    }
}

#[cfg(test)]
pub(crate) mod store_tests {
    //! Conformance suite run against every store implementation.
    use std::sync::Arc;

    use super::*;

    pub fn push_req(node: usize, round: u64, val: f32) -> PushRequest {
        PushRequest {
            node_id: node,
            round,
            epoch: round,
            n_examples: 100 + node as u64,
            params: Arc::new(FlatParams(vec![val; 8])),
        }
    }

    pub fn conformance(store: &dyn WeightStore) {
        // empty
        assert!(store.latest_per_node().unwrap().is_empty());
        let h0 = store.state_hash().unwrap();

        // push two nodes
        store.push(push_req(0, 0, 1.0)).unwrap();
        let h1 = store.state_hash().unwrap();
        assert_ne!(h0, h1, "state hash must change on push");
        store.push(push_req(1, 0, 2.0)).unwrap();

        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 2);
        let r0 = store.entries_for_round(0).unwrap();
        assert_eq!(r0.len(), 2);
        assert!(store.entries_for_round(1).unwrap().is_empty());

        // node 0 pushes a newer entry: latest_per_node must pick it
        store.push(push_req(0, 1, 3.0)).unwrap();
        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 2);
        let e0 = latest.iter().find(|e| e.node_id == 0).unwrap();
        assert_eq!(e0.round, 1);
        assert_eq!(e0.params.0[0], 3.0);
        // seq strictly increases
        let e1 = latest.iter().find(|e| e.node_id == 1).unwrap();
        assert!(e0.seq > e1.seq);

        // payload integrity
        assert_eq!(e1.params.0, vec![2.0; 8]);
        assert_eq!(e1.n_examples, 101);

        // clear
        store.clear().unwrap();
        assert!(store.latest_per_node().unwrap().is_empty());
        assert!(store.entries_for_round(0).unwrap().is_empty());
    }

    pub fn concurrent_pushes(store: Arc<dyn WeightStore>) {
        let threads: Vec<_> = (0..8)
            .map(|node| {
                let s = Arc::clone(&store);
                std::thread::spawn(move || {
                    for round in 0..20 {
                        s.push(push_req(node, round, node as f32)).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let latest = store.latest_per_node().unwrap();
        assert_eq!(latest.len(), 8);
        for e in &latest {
            assert_eq!(e.round, 19, "node {} latest round", e.node_id);
            assert_eq!(e.params.0[0], e.node_id as f32);
        }
        assert_eq!(store.push_count(), 160);
    }
}
