//! One end-to-end federated experiment (a single trial).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{ExperimentConfig, StoreKind};
use crate::data::{
    BatchLoader, DataSource, DatasetKind, Partitioner, Split, SynthDataset, TextCorpus,
};
use crate::metrics::timeline::{render_ascii, Timeline};
use crate::metrics::{EventField, RunLogger};
use crate::node::{spawn_node, NodeCtx, NodeReport, NodeRunner, NodeStatus};
use crate::runtime::{Engine, Manifest, ModelBundle, ModelInfo};
use crate::par::ChunkPool;
use crate::sched::{EventExecutor, ParticipationPlan, SchedulerKind, Task, TaskClock};
use crate::store::{
    AdversaryStore, FsStore, LatencyStore, MemoryStore, ShardedStore, WeightStore,
};
use crate::tensor::flat::weighted_average_pooled;
use crate::tensor::FlatParams;
use crate::time::Clock;
use crate::trace::{
    compute_divergence, DivergenceReport, FaultTotals, NodeSpanSummary, RunSummary, Tracer,
};

/// Outcome of one experiment run.
#[derive(Debug)]
pub struct ExperimentResult {
    /// Accuracy of the aggregated global model on the held-out test set.
    pub final_accuracy: f64,
    /// Mean test loss of the global model.
    pub final_loss: f64,
    /// Seconds from node spawn to last node exit, on the experiment's
    /// clock: real seconds under `clock = real`, *simulated* seconds
    /// under `clock = virtual` (where a straggler grid runs in
    /// milliseconds of real time but still reports its faithful
    /// simulated duration).
    pub wall_clock_s: f64,
    /// Per-node reports (status, metrics, timeline), in node-id order.
    pub reports: Vec<NodeReport>,
    /// Content digest of the aggregated global model
    /// ([`crate::tensor::FlatParams::content_hash`], the chunked
    /// change-detection hash): two runs replayed from the same config
    /// produce the same digest, so a single u64 comparison detects any
    /// weight-level divergence — the replay check `fedbench run` prints
    /// and the determinism suite asserts across thread counts. `0` when
    /// no global model was produced (synthetic trial runners).
    pub global_hash: u64,
    /// Total pushes observed by the store.
    pub store_pushes: u64,
    /// Fraction of wall-clock the average node spent blocked on the sync
    /// barrier (the Figure-1 quantity; ~0 for async).
    pub mean_idle_fraction: f64,
    /// True iff every node ran all its epochs.
    pub all_completed: bool,
    /// Round-history divergence analytics computed from the store's
    /// round archive (`None` when tracing was off or no round had
    /// archived client updates). Feeds the sweep report's divergence
    /// column and the `fedbench inspect` tables.
    pub divergence: Option<DivergenceReport>,
    /// Directory the structured trace was exported into
    /// (`trace.jsonl` + `trace_chrome.json` + `analysis.json`); `None`
    /// when tracing was off or no `log_dir` was configured.
    pub trace_dir: Option<PathBuf>,
}

impl ExperimentResult {
    /// Figure-1-style ASCII rendering of the node timelines.
    pub fn render_timelines(&self, width: usize) -> String {
        let tls: Vec<&Timeline> = self.reports.iter().map(|r| &r.timeline).collect();
        render_ascii(&tls, width)
    }

    /// Fleet-wide fault-layer totals folded from the per-node reports
    /// (all zero on a clean run).
    pub fn fault_totals(&self) -> FaultTotals {
        let mut f = FaultTotals::default();
        for r in &self.reports {
            f.injected_faults += r.injected_faults;
            f.store_retries += r.store_retries;
            f.store_give_ups += r.store_give_ups;
            f.degraded_rounds += r.degraded_rounds;
            f.restarts += r.restarts;
        }
        f
    }

    /// Experiment-wide weight-store traffic: every node's
    /// [`crate::metrics::TrafficMeter`] merged (encoded wire bytes,
    /// blob headers included).
    pub fn total_traffic(&self) -> crate::metrics::TrafficMeter {
        let mut total = crate::metrics::TrafficMeter::default();
        for r in &self.reports {
            total.merge(&r.timeline.traffic);
        }
        total
    }

    /// Distill this result into the [`RunSummary`] the trace subsystem
    /// renders and exports — the *same* numbers `fedbench inspect`
    /// reads back from `analysis.json`, so the live `fedbench run`
    /// summary and the post-hoc one can never disagree.
    pub fn run_summary(&self, run_name: &str) -> RunSummary {
        RunSummary {
            run_name: run_name.to_string(),
            n_nodes: self.reports.len(),
            wall_clock_s: self.wall_clock_s,
            global_digest: self.global_hash,
            store_pushes: self.store_pushes,
            mean_idle_fraction: self.mean_idle_fraction,
            all_completed: self.all_completed,
            faults: self.fault_totals(),
            nodes: self
                .reports
                .iter()
                .map(|r| {
                    NodeSpanSummary::from_timeline(
                        &r.timeline,
                        r.status == NodeStatus::Completed,
                    )
                })
                .collect(),
            divergence: self.divergence.clone(),
        }
    }
}

/// Build the configured store stack on the experiment's clock, so change
/// waits and injected latency run in the same time domain as the nodes
/// (a virtual-clocked node parked on a real-clocked store would freeze
/// simulated time forever).
fn build_store(cfg: &ExperimentConfig, clock: &Arc<dyn Clock>) -> Result<Arc<dyn WeightStore>> {
    let base: Arc<dyn WeightStore> = match &cfg.store {
        StoreKind::Memory => Arc::new(MemoryStore::with_clock(Arc::clone(clock))),
        StoreKind::Sharded(n) => Arc::new(ShardedStore::with_clock(*n, Arc::clone(clock))),
        StoreKind::Fs(path) => Arc::new(FsStore::open_with_clock(path, Arc::clone(clock))?),
    };
    let wired: Arc<dyn WeightStore> = match cfg.latency {
        None => base,
        // Arc<dyn WeightStore> implements WeightStore, so wrappers stack.
        Some(lat) => {
            Arc::new(LatencyStore::with_clock(base, lat, cfg.seed, Arc::clone(clock)))
        }
    };
    // The adversary wraps *outermost* (client side of the wire): a
    // malicious client corrupts its update before upload, so the
    // rewritten weights pay real latency/traffic like any honest push.
    Ok(match cfg.adversary {
        None => wired,
        Some(spec) => {
            Arc::new(AdversaryStore::new(wired, spec, cfg.n_nodes, cfg.seed))
        }
    })
}

/// Build per-node train loaders + a test loader for the configured model.
fn build_data(
    cfg: &ExperimentConfig,
    info: &crate::runtime::ModelInfo,
) -> Result<(Vec<BatchLoader>, BatchLoader)> {
    let batch_size = info.batch_size;
    let num_classes = info.num_classes;
    if cfg.model.starts_with("lm") {
        // LM: corpus windows, random split across nodes (the paper applies
        // label skew only to the classification datasets).
        let seq_len = info.input_shape[0] - 1; // input_shape = [seq_len + 1]
        let train = Arc::new(TextCorpus::generate(cfg.seed ^ 0xC0, cfg.train_size * seq_len + 1));
        let test = Arc::new(TextCorpus::generate(cfg.seed ^ 0xC1, cfg.test_size * seq_len + 1));
        let n_windows = train.num_windows(seq_len);
        let labels = vec![0usize; n_windows];
        let parts = Partitioner::new(cfg.n_nodes, 0.0, 1.max(num_classes)).assign(&labels, cfg.seed);
        let loaders = parts
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                BatchLoader::new(
                    DataSource::Text { corpus: Arc::clone(&train), seq_len },
                    shard,
                    batch_size,
                    cfg.seed ^ ((i as u64) << 8),
                )
            })
            .collect();
        let n_test = test.num_windows(seq_len);
        let test_loader = BatchLoader::new(
            DataSource::Text { corpus: test, seq_len },
            (0..n_test).collect(),
            batch_size,
            cfg.seed ^ 0xEE,
        );
        Ok((loaders, test_loader))
    } else {
        let kind = DatasetKind::parse(&cfg.model)
            .with_context(|| format!("unknown dataset for model {:?}", cfg.model))?;
        let ds = Arc::new(SynthDataset::new(kind, cfg.seed, cfg.train_size, cfg.test_size));
        let labels = ds.labels(Split::Train);
        let parts =
            Partitioner::new(cfg.n_nodes, cfg.skew, kind.num_classes()).assign(&labels, cfg.seed);
        let loaders = parts
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                BatchLoader::new(
                    DataSource::Image { ds: Arc::clone(&ds), split: Split::Train },
                    shard,
                    batch_size,
                    cfg.seed ^ ((i as u64) << 8),
                )
            })
            .collect();
        let test_loader = BatchLoader::new(
            DataSource::Image { ds, split: Split::Test },
            (0..cfg.test_size).collect(),
            batch_size,
            cfg.seed ^ 0xEE,
        );
        Ok((loaders, test_loader))
    }
}

/// Run one federated experiment end-to-end and evaluate the global model.
///
/// Each node federates through the [`crate::protocol::FederationProtocol`]
/// resolved from `cfg.mode` (sync barrier, async Algorithm 1,
/// `gossip[:m]`, or the local baseline); the driver itself is
/// protocol-agnostic.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentResult> {
    cfg.validate()?;
    let manifest = Arc::new(Manifest::discover()?);
    let info = manifest.model(&cfg.model)?.clone();
    if cfg.scheduler == SchedulerKind::Events {
        return run_experiment_events(cfg, &info);
    }

    // The experiment's time domain (`clock = real | virtual`): one fresh
    // clock per trial, shared by nodes, stores, and timelines.
    let clock: Arc<dyn Clock> = cfg.clock.build();

    let (loaders, test_loader) = build_data(cfg, &info)?;
    let store = build_store(cfg, &clock)?;
    store.clear()?; // fresh namespace per trial (like a new bucket prefix)

    let logger = match &cfg.log_dir {
        Some(dir) => Some(Arc::new(RunLogger::create(dir.join(cfg.run_name()))?)),
        None => None,
    };

    // one shared participation schedule so the per-round cohort shuffle
    // runs once, not once per node
    let plan = Arc::new(ParticipationPlan::new(
        cfg.participation,
        cfg.availability,
        cfg.seed,
        cfg.n_nodes,
    ));
    let tracer = cfg.trace.then(|| Arc::new(Tracer::new(cfg.n_nodes)));

    let t0 = clock.now();
    let start = Arc::new(std::sync::Barrier::new(cfg.n_nodes));
    let mut handles = Vec::new();
    for (node_id, loader) in loaders.into_iter().enumerate() {
        let ctx = NodeCtx {
            node_id,
            cfg: Arc::new(cfg.clone()),
            manifest: Arc::clone(&manifest),
            store: Arc::clone(&store),
            strategy: cfg.strategy.build(),
            loader,
            clock: Arc::clone(&clock),
            plan: Arc::clone(&plan),
            start: Arc::clone(&start),
            logger: logger.clone(),
            tracer: tracer.clone(),
        };
        handles.push(spawn_node(ctx));
    }
    let reports: Vec<NodeReport> = handles.into_iter().map(NodeHandleExt::wait_report).collect();
    let wall_clock_s = clock.now().saturating_sub(t0).as_secs_f64();

    // evaluation engine + bundle are built fresh here (node engines live
    // on their own threads)
    let engine = Engine::new()?;
    let bundle = ModelBundle::load(&engine, &info)?;
    assemble_result(cfg, &bundle, &test_loader, &store, &logger, &tracer, reports, wall_clock_s)
}

/// The `scheduler = events` path: every node is a [`NodeRunner`] task on
/// one [`EventExecutor`] thread, sharing a single PJRT engine + model
/// bundle — the allocation profile that lets one process hold a
/// 10k-client fleet. Simulated timelines and model digests are
/// bit-identical to the threaded path on latency-free scenarios with
/// distinct per-node delays (the conformance goldens).
fn run_experiment_events(cfg: &ExperimentConfig, info: &ModelInfo) -> Result<ExperimentResult> {
    // validation enforced clock = virtual; the TaskClock *is* the
    // executor's virtual time domain, with identical reported timelines
    let task_clock = Arc::new(TaskClock::new());
    let clock: Arc<dyn Clock> = Arc::clone(&task_clock) as Arc<dyn Clock>;

    let (loaders, test_loader) = build_data(cfg, info)?;
    let store = build_store(cfg, &clock)?;
    store.clear()?;

    let logger = match &cfg.log_dir {
        Some(dir) => Some(Arc::new(RunLogger::create(dir.join(cfg.run_name()))?)),
        None => None,
    };

    // ONE engine + bundle for the whole fleet (and the final evaluation):
    // the runners borrow it, so it must outlive them
    let engine = Engine::new()?;
    let bundle = ModelBundle::load(&engine, info)?;

    let cfg_arc = Arc::new(cfg.clone());
    let plan = Arc::new(ParticipationPlan::new(
        cfg.participation,
        cfg.availability,
        cfg.seed,
        cfg.n_nodes,
    ));
    let tracer = cfg.trace.then(|| Arc::new(Tracer::new(cfg.n_nodes)));
    let t0 = clock.now();
    let mut runners: Vec<NodeRunner> = loaders
        .into_iter()
        .enumerate()
        .map(|(node_id, loader)| {
            NodeRunner::new(
                node_id,
                Arc::clone(&cfg_arc),
                Arc::clone(&store),
                Arc::clone(&clock),
                logger.clone(),
                Arc::clone(&plan),
                cfg.strategy.build(),
                loader,
                &bundle,
                tracer.clone(),
            )
        })
        .collect::<Result<_>>()?;

    let executor = EventExecutor::new(Arc::clone(&task_clock), Arc::clone(&store));
    let mut tasks: Vec<&mut dyn Task> =
        runners.iter_mut().map(|r| r as &mut dyn Task).collect();
    executor.run(&mut tasks)?;
    drop(tasks);

    let reports: Vec<NodeReport> = runners.into_iter().map(NodeRunner::into_report).collect();
    let wall_clock_s = clock.now().saturating_sub(t0).as_secs_f64();
    assemble_result(cfg, &bundle, &test_loader, &store, &logger, &tracer, reports, wall_clock_s)
}

/// Shared result assembly: aggregate the global model, evaluate it, fold
/// the per-node reports into the experiment-level metrics. Identical for
/// both schedulers, so the two paths cannot drift apart.
#[allow(clippy::too_many_arguments)] // one internal seam shared by both scheduler paths
fn assemble_result(
    cfg: &ExperimentConfig,
    bundle: &ModelBundle,
    test_loader: &BatchLoader,
    store: &Arc<dyn WeightStore>,
    logger: &Option<Arc<RunLogger>>,
    tracer: &Option<Arc<Tracer>>,
    reports: Vec<NodeReport>,
    wall_clock_s: f64,
) -> Result<ExperimentResult> {
    // ---- global model = example-weighted average of the nodes' final
    // weights (what the store would converge to; identical to any node's
    // last sync aggregation in sync mode, and the one-shot average of
    // independent silos under multi-node local mode).
    let finals: Vec<(&FlatParams, f32)> = reports
        .iter()
        .filter_map(|r| r.final_params.as_ref().map(|p| (p, r.n_examples_per_epoch as f32)))
        .collect();
    anyhow::ensure!(
        !finals.is_empty(),
        "no node produced final weights; statuses: {:?}",
        reports.iter().map(|r| &r.status).collect::<Vec<_>>()
    );
    let total: f32 = finals.iter().map(|(_, n)| n).sum();
    let weights: Vec<f32> = finals.iter().map(|(_, n)| n / total).collect();
    let params_refs: Vec<&FlatParams> = finals.iter().map(|(p, _)| *p).collect();
    // same kernel pool as the node threads (threads = auto | N);
    // bit-identical to the sequential average for any thread count
    let pool = ChunkPool::from_config(cfg.threads);
    let global = weighted_average_pooled(&params_refs, &weights, pool);
    // replay digest: one u64 that detects any weight-level divergence
    // between runs of the same config (chunked change-detection hash)
    let global_hash = global.content_hash_pooled(pool);

    // ---- evaluate on the un-partitioned test set (paper §4.1)
    let batches = test_loader.full_batches();
    let (final_loss, final_accuracy) = bundle.evaluate(&global, &batches)?;

    // .max(1) so a (hypothetical) zero-report result yields 0.0, not NaN
    let mean_idle_fraction = reports
        .iter()
        .map(|r| r.timeline.idle_fraction())
        .sum::<f64>()
        / reports.len().max(1) as f64;
    let all_completed = reports.iter().all(|r| r.status == NodeStatus::Completed);

    // ---- round-history analytics: replay the store's round archive
    // into per-round divergence (client update vs round aggregate),
    // with the same deterministic pooled kernels as aggregation
    let divergence = if cfg.trace {
        compute_divergence(store.as_ref(), cfg.epochs as u64, pool)?
    } else {
        None
    };

    if let Some(lg) = &logger {
        let _ = lg.log_event_typed(
            "experiment_done",
            &[
                ("accuracy", EventField::Num(final_accuracy)),
                ("loss", EventField::Num(final_loss)),
                ("wall_clock_s", EventField::Num(wall_clock_s)),
                ("global_hash", EventField::Str(format!("{global_hash:016x}"))),
                (
                    "mean_divergence",
                    match divergence.as_ref().and_then(|d| d.mean_l2()) {
                        Some(l2) => EventField::Num(l2),
                        None => EventField::Str("none".into()),
                    },
                ),
            ],
        );
    }

    let mut result = ExperimentResult {
        final_accuracy,
        final_loss,
        wall_clock_s,
        global_hash,
        store_pushes: store.push_count(),
        mean_idle_fraction,
        all_completed,
        reports,
        divergence,
        trace_dir: None,
    };

    // ---- trace export (trace.jsonl + trace_chrome.json + analysis.json)
    // into the run directory; `fedbench inspect` reads these back, and
    // `fedbench run` prints the very same RunSummary
    if let (Some(lg), Some(tr)) = (&logger, &tracer) {
        let timelines: Vec<&Timeline> =
            result.reports.iter().map(|r| &r.timeline).collect();
        let summary = result.run_summary(&cfg.run_name());
        result.trace_dir =
            Some(crate::trace::export_run(lg.dir(), tr, &timelines, &summary)?);
    }
    Ok(result)
}

trait NodeHandleExt {
    fn wait_report(self) -> NodeReport;
}

impl NodeHandleExt for crate::node::NodeHandle {
    fn wait_report(self) -> NodeReport {
        self.wait()
    }
}
