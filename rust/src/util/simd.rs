//! Runtime SIMD dispatch for the wire-path kernels.
//!
//! One rule, applied everywhere a kernel has a vector body
//! ([`crate::compress::q8`]'s quantizer, dequantizer): the scalar
//! expression is the *specification*, and a SIMD body is only ever an
//! alternative evaluation order of bit-identical arithmetic. Dispatch is
//! a runtime CPU check — never a compile-time `target-feature` bet — so
//! one binary runs correctly from a feature-poor VM to an AVX2 host, and
//! the `rust/tests/determinism.rs` thread-invariance contract holds on
//! all of them (chunk boundaries are constants; lane width, like thread
//! count, never leaks into results).
//!
//! Setting the `FEDLESS_NO_SIMD` environment variable (any value) before
//! first use forces the scalar bodies — the escape hatch for A/B
//! debugging and for the bench baselines in `rust/benches/kernels.rs`.

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-wide override: `true` disables SIMD bodies even where the CPU
/// supports them (see [`set_simd_enabled`]).
static SIMD_DISABLED: AtomicBool = AtomicBool::new(false);

fn avx2_detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::OnceLock;
        static DETECTED: OnceLock<bool> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            std::env::var_os("FEDLESS_NO_SIMD").is_none() && is_x86_feature_detected!("avx2")
        })
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// True when kernels should take their AVX2 bodies: the CPU supports
/// AVX2, `FEDLESS_NO_SIMD` is unset, and [`set_simd_enabled`] hasn't
/// turned them off. Kernels produce bit-identical results either way —
/// this only selects an evaluation order.
#[inline]
pub fn simd_enabled() -> bool {
    avx2_detected() && !SIMD_DISABLED.load(Ordering::Relaxed)
}

/// Force-disable (`false`) or re-allow (`true`) the SIMD bodies at
/// runtime. A process-wide toggle for benches measuring the scalar
/// baseline and for bisecting a suspected codegen issue; results are
/// bit-identical either way, so flipping it mid-run is safe but changes
/// only throughput.
pub fn set_simd_enabled(on: bool) {
    SIMD_DISABLED.store(!on, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toggle_round_trips() {
        // Never assert on the detection result (CI may run anywhere);
        // only that the override always forces scalar.
        let initial = simd_enabled();
        set_simd_enabled(false);
        assert!(!simd_enabled());
        set_simd_enabled(true);
        assert_eq!(simd_enabled(), initial, "re-enabling restores detection");
    }
}
