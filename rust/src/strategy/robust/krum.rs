//! [`Krum`] — single-update selection aggregation (Blanchard et al. 2017).

use crate::par::ChunkPool;
use crate::tensor::flat::PAR_CHUNK;
use crate::tensor::FlatParams;

use super::super::{Contribution, Strategy};
use super::{by_node, common_len};

/// Krum selection: score every update by the sum of its squared
/// distances to its `n − f − 2` nearest peers and adopt the update with
/// the smallest score verbatim. With `n ≥ f + 3` and at most `f`
/// Byzantine clients, the selected update is always one pushed by an
/// honest client. Ties break toward the lowest node id, so selection is
/// invariant under client-order permutations.
#[derive(Clone, Copy, Debug)]
pub struct Krum {
    f: usize,
}

impl Krum {
    /// Tolerate up to `f` Byzantine clients.
    pub fn new(f: usize) -> Self {
        Krum { f }
    }

    /// The configured Byzantine tolerance.
    pub fn f(&self) -> usize {
        self.f
    }

    /// Index (into the node-id-sorted contributions) of the selected
    /// update. Exposed for the property tests in `rust/tests/robust.rs`.
    pub fn select(&self, sorted: &[&Contribution], pool: ChunkPool) -> usize {
        let m = sorted.len();
        if m == 1 {
            return 0;
        }
        let dist = pairwise_sq_dists(sorted, pool);
        // cohorts too small for the textbook n - f - 2 neighbourhood fall
        // back to the nearest single peer
        let k = m.saturating_sub(self.f + 2).clamp(1, m - 1);
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for a in 0..m {
            let mut to_others: Vec<f64> =
                (0..m).filter(|b| *b != a).map(|b| dist[a * m + b]).collect();
            to_others.sort_unstable_by(f64::total_cmp);
            let score: f64 = to_others[..k].iter().sum();
            // strict less-than keeps the earliest (lowest node id) winner
            if score < best_score {
                best_score = score;
                best = a;
            }
        }
        best
    }
}

/// Symmetric `m × m` matrix of pairwise squared L2 distances, computed
/// as fixed-[`PAR_CHUNK`] partial sums combined in chunk-index order
/// (bit-identical for any thread count).
fn pairwise_sq_dists(sorted: &[&Contribution], pool: ChunkPool) -> Vec<f64> {
    let m = sorted.len();
    let n = common_len(sorted);
    let n_chunks = n.div_ceil(PAR_CHUNK).max(1);
    let partials: Vec<Vec<f64>> = pool.map((0..n_chunks).collect(), |_, ci| {
        let lo = ci * PAR_CHUNK;
        let hi = (lo + PAR_CHUNK).min(n);
        let mut d = vec![0.0f64; m * m];
        for a in 0..m {
            let xa = &sorted[a].params.as_slice()[lo..hi];
            for b in (a + 1)..m {
                let xb = &sorted[b].params.as_slice()[lo..hi];
                let mut acc = 0.0f64;
                for (p, q) in xa.iter().zip(xb) {
                    let diff = (*p - *q) as f64;
                    acc += diff * diff;
                }
                d[a * m + b] = acc;
            }
        }
        d
    });
    let mut dist = vec![0.0f64; m * m];
    for part in &partials {
        for (acc, v) in dist.iter_mut().zip(part) {
            *acc += *v;
        }
    }
    for a in 0..m {
        for b in (a + 1)..m {
            dist[b * m + a] = dist[a * m + b];
        }
    }
    dist
}

impl Strategy for Krum {
    fn name(&self) -> &'static str {
        "krum"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let sorted = by_node(contribs);
        let best = self.select(&sorted, pool);
        Some((*sorted[best].params).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn selects_clustered_update_over_outlier() {
        let cs = [
            contrib(0, 100, true, &[1.0, 1.0]),
            contrib(1, 100, false, &[1.1, 0.9]),
            contrib(2, 100, false, &[0.9, 1.1]),
            contrib(3, 100, false, &[500.0, -500.0]),
        ];
        let out = Krum::new(1).aggregate(&cs).unwrap();
        // output is one of the clustered honest vectors, verbatim
        assert!(cs[..3].iter().any(|c| *c.params == out), "picked {:?}", out.0);
    }

    #[test]
    fn single_contribution_is_identity() {
        let cs = [contrib(0, 100, true, &[7.0, -3.0])];
        let out = Krum::new(1).aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![7.0, -3.0]);
    }

    #[test]
    fn tie_breaks_toward_lowest_node_id() {
        // two identical honest pairs: scores tie at 0, node 0 wins
        let cs = [
            contrib(1, 100, false, &[2.0]),
            contrib(0, 100, true, &[2.0]),
            contrib(2, 100, false, &[2.0]),
        ];
        let sorted = by_node(&cs);
        assert_eq!(Krum::new(0).select(&sorted, ChunkPool::sequential()), 0);
    }

    #[test]
    fn distances_are_thread_invariant() {
        let n = PAR_CHUNK + 3;
        let cs: Vec<Contribution> = (0..4)
            .map(|k| {
                let vals: Vec<f32> = (0..n).map(|i| ((i + 31 * k) as f32 * 0.007).cos()).collect();
                contrib(k, 100, k == 0, &vals)
            })
            .collect();
        let sorted = by_node(&cs);
        let seq = pairwise_sq_dists(&sorted, ChunkPool::sequential());
        for threads in [2, 8] {
            assert_eq!(seq, pairwise_sq_dists(&sorted, ChunkPool::new(threads)), "threads={threads}");
        }
    }
}
