//! Artifact-free trial harness for the event executor — the
//! protocol-level twin of the threaded `run_sim` harness in
//! `rust/tests/timing.rs`.
//!
//! Each simulated node is a [`Task`] that per epoch: checks its crash
//! and participation schedule, "trains" by sleeping its per-node delay
//! on the [`TaskClock`], then drives its protocol's
//! [`crate::protocol::FederationProtocol::poll_epoch`] until the epoch
//! federates or stalls. No PJRT, no artifacts — pure protocol + store +
//! clock, which is what the conformance tests compare against the
//! threaded harness and what the 10k-client scale test runs.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::compress::{CodecKind, CodecState};
use crate::config::{ExperimentConfig, FederationMode};
use crate::metrics::timeline::{Span, SpanKind, Timeline};
use crate::protocol::{EpochCtx, EpochStep, FederationProtocol, ProtocolKind};
use crate::store::{MemoryStore, WeightStore};
use crate::strategy::{Strategy, StrategyKind};
use crate::tensor::FlatParams;
use crate::time::Clock;

use super::{
    AvailabilitySpec, EventExecutor, ParticipationPlan, StepOutcome, Task, TaskClock,
};

/// One executor-harness trial: `delays.len()` simulated nodes, FedAvg
/// aggregation, a fresh in-memory store on a fresh [`TaskClock`].
pub struct TrialSpec {
    /// Federation mode (drives [`ProtocolKind`]).
    pub mode: FederationMode,
    /// Per-node per-epoch training delay; its length is the fleet size.
    pub delays: Vec<Duration>,
    /// Epochs per node.
    pub epochs: usize,
    /// Sync-barrier stall timeout.
    pub sync_timeout: Duration,
    /// `(node, epoch)`: that node exits at the start of that epoch
    /// without pushing (the §4.2.1 crash scenario).
    pub crash: Option<(usize, usize)>,
    /// Per-round cohort fraction in `(0, 1]`.
    pub participation: f64,
    /// Availability trace.
    pub availability: AvailabilitySpec,
    /// Trial seed (cohorts, availability, gossip schedules).
    pub seed: u64,
    /// Wire codec for pushes.
    pub compress: CodecKind,
    /// Kernel pool width (the config `threads` knob): a pure wall-clock
    /// knob — results are bit-identical for any value.
    pub threads: usize,
    /// Initial weights per node (the threaded harness uses
    /// `FlatParams(vec![node_id as f32; 4])` so averaging is visible).
    pub init: fn(usize) -> FlatParams,
    /// Optional structured tracer ([`crate::trace`]): when set, each
    /// node emits train spans and push/pull/aggregate instants stamped
    /// on the trial's [`TaskClock`]. `None` (the default) costs nothing.
    pub tracer: Option<Arc<crate::trace::Tracer>>,
}

impl TrialSpec {
    /// The conformance-default spec: full participation, no crash, no
    /// compression, the threaded harness's initial weights, seed from
    /// the default config.
    pub fn new(mode: FederationMode, delays: Vec<Duration>, epochs: usize) -> TrialSpec {
        TrialSpec {
            mode,
            delays,
            epochs,
            sync_timeout: Duration::from_secs(3600),
            crash: None,
            participation: 1.0,
            availability: AvailabilitySpec::None,
            seed: ExperimentConfig::default().seed,
            compress: CodecKind::default(),
            threads: ExperimentConfig::default().threads,
            init: |node_id| FlatParams(vec![node_id as f32; 4]),
            tracer: None,
        }
    }
}

/// What one simulated node reports back (mirrors the threaded harness's
/// `SimNode`).
pub struct SimNodeResult {
    /// The node's id.
    pub node_id: usize,
    /// Simulated instant the node finished (completion, crash or stall).
    pub finish: Duration,
    /// The node's recorded timeline spans.
    pub spans: Vec<Span>,
    /// Final local weights.
    pub params: FlatParams,
    /// Whether the node stalled at a sync barrier.
    pub stalled: bool,
    /// The node's wire-traffic accounting.
    pub traffic: crate::metrics::TrafficMeter,
}

enum Phase {
    Train,
    Federate,
}

struct SimNode {
    node_id: usize,
    cfg: Arc<ExperimentConfig>,
    store: Arc<dyn WeightStore>,
    clock: Arc<TaskClock>,
    plan: Arc<ParticipationPlan>,
    delay: Duration,
    protocol: Box<dyn FederationProtocol>,
    strategy: Box<dyn Strategy>,
    codec: CodecState,
    timeline: Timeline,
    params: FlatParams,
    epoch: usize,
    phase: Phase,
    stalled: bool,
    finish: Duration,
    tracer: Option<Arc<crate::trace::Tracer>>,
}

impl SimNode {
    fn finish_now(&mut self) -> StepOutcome {
        self.finish = self.clock.now();
        StepOutcome::Done
    }
}

impl Task for SimNode {
    fn step(&mut self) -> StepOutcome {
        match self.phase {
            Phase::Train => {
                // Zero-time skips (finished epochs, crash, off-cohort
                // rounds) loop inline; anything that advances the clock
                // or touches the store ends the step so the executor can
                // interleave peers.
                loop {
                    if self.epoch >= self.cfg.epochs {
                        return self.finish_now();
                    }
                    if self.cfg.crash.as_ref().is_some_and(|c| {
                        c.node == self.node_id && c.at_epoch == self.epoch
                    }) {
                        return self.finish_now(); // dies without pushing
                    }
                    if !self.plan.participates(self.node_id, self.epoch) {
                        self.epoch += 1; // off-cohort: zero simulated time
                        continue;
                    }
                    break;
                }
                let t = self.clock.now();
                self.clock
                    .sleep(self.delay.mul_f64(self.plan.delay_multiplier(self.node_id)));
                self.timeline.record(SpanKind::Train, t, self.clock.now());
                if let Some(tracer) = &self.tracer {
                    tracer.span(
                        self.node_id,
                        self.epoch as u64,
                        t,
                        self.clock.now(),
                        crate::trace::TraceEventKind::Train,
                    );
                }
                self.phase = Phase::Federate;
                StepOutcome::Yield
            }
            Phase::Federate => {
                let mut ctx = EpochCtx {
                    node_id: self.node_id,
                    n_nodes: self.cfg.n_nodes,
                    round_k: self.plan.round_k(self.epoch),
                    epoch: self.epoch,
                    n_examples: 100,
                    store: self.store.as_ref(),
                    strategy: self.strategy.as_mut(),
                    timeline: &mut self.timeline,
                    sync_timeout: self.cfg.sync_timeout,
                    clock: self.clock.as_ref() as &dyn Clock,
                    codec: &mut self.codec,
                    pool: crate::par::ChunkPool::from_config(self.cfg.threads),
                    tracer: self.tracer.as_deref(),
                };
                match self
                    .protocol
                    .poll_epoch(&mut ctx, &mut self.params)
                    .expect("in-memory harness protocols cannot fail")
                {
                    EpochStep::Wait { since, timeout } => StepOutcome::Wait { since, timeout },
                    EpochStep::Done(out) => {
                        if out.stalled_at.is_some() {
                            self.stalled = true;
                            return self.finish_now();
                        }
                        self.epoch += 1;
                        self.phase = Phase::Train;
                        StepOutcome::Yield
                    }
                }
            }
        }
    }
}

/// Run one trial on the event executor and return per-node results in
/// node-id order.
pub fn run_events_trial(spec: &TrialSpec) -> Result<Vec<SimNodeResult>> {
    run_events_trial_captured(spec).map(|(nodes, _)| nodes)
}

/// [`run_events_trial`] that also hands back the trial's store, so
/// callers can replay its round archive through the
/// [`crate::trace::analyze`] divergence analytics.
pub fn run_events_trial_captured(
    spec: &TrialSpec,
) -> Result<(Vec<SimNodeResult>, Arc<dyn WeightStore>)> {
    let n = spec.delays.len();
    let clock = Arc::new(TaskClock::new());
    let cfg = Arc::new(ExperimentConfig {
        mode: spec.mode,
        n_nodes: n,
        epochs: spec.epochs,
        sync_timeout: spec.sync_timeout,
        seed: spec.seed,
        compress: spec.compress,
        threads: spec.threads,
        crash: spec.crash.map(|(node, at_epoch)| crate::config::CrashSpec { node, at_epoch }),
        ..Default::default()
    });
    let store: Arc<dyn WeightStore> =
        Arc::new(MemoryStore::with_clock(Arc::clone(&clock) as Arc<dyn Clock>));
    let plan = Arc::new(ParticipationPlan::new(
        spec.participation,
        spec.availability,
        spec.seed,
        n,
    ));
    let mut nodes: Vec<SimNode> = (0..n)
        .map(|node_id| SimNode {
            node_id,
            cfg: Arc::clone(&cfg),
            store: Arc::clone(&store),
            clock: Arc::clone(&clock),
            plan: Arc::clone(&plan),
            delay: spec.delays[node_id],
            protocol: ProtocolKind::from(cfg.mode).build(node_id, &cfg),
            strategy: StrategyKind::FedAvg.build(),
            codec: CodecState::new(cfg.compress),
            timeline: Timeline::new(node_id),
            params: (spec.init)(node_id),
            epoch: 0,
            phase: Phase::Train,
            stalled: false,
            finish: Duration::ZERO,
            tracer: spec.tracer.clone(),
        })
        .collect();

    let executor = EventExecutor::new(Arc::clone(&clock), Arc::clone(&store));
    let mut tasks: Vec<&mut dyn Task> =
        nodes.iter_mut().map(|t| t as &mut dyn Task).collect();
    executor.run(&mut tasks)?;

    let results = nodes
        .into_iter()
        .map(|node| SimNodeResult {
            node_id: node.node_id,
            finish: node.finish,
            traffic: node.timeline.traffic,
            spans: node.timeline.spans,
            params: node.params,
            stalled: node.stalled,
        })
        .collect();
    Ok((results, store))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn async_straggler_finishes_on_analytic_schedule() {
        let spec = TrialSpec::new(FederationMode::Async, vec![ms(50), ms(500)], 5);
        let nodes = run_events_trial(&spec).unwrap();
        assert_eq!(nodes[0].finish, ms(250), "fast node: 5 × 50ms");
        assert_eq!(nodes[1].finish, ms(2500), "straggler: 5 × 500ms");
        assert!(!nodes[0].stalled && !nodes[1].stalled);
    }

    #[test]
    fn sync_barrier_drags_everyone_to_the_straggler_and_converges() {
        let spec = TrialSpec::new(FederationMode::Sync, vec![ms(50), ms(500)], 3);
        let nodes = run_events_trial(&spec).unwrap();
        // both nodes finish at the straggler's pace, exactly
        assert_eq!(nodes[0].finish, ms(1500));
        assert_eq!(nodes[1].finish, ms(1500));
        // FedAvg over identical-weight contributions: (0 + 1)/2
        assert_eq!(nodes[0].params.0, vec![0.5; 4]);
        assert_eq!(nodes[0].params.0, nodes[1].params.0);
    }

    #[test]
    fn crash_stalls_sync_survivors_after_the_simulated_timeout() {
        let mut spec =
            TrialSpec::new(FederationMode::Sync, vec![ms(50), ms(70), ms(230)], 3);
        spec.sync_timeout = Duration::from_secs(300);
        spec.crash = Some((2, 1));
        let nodes = run_events_trial(&spec).unwrap();
        for survivor in &nodes[0..2] {
            assert!(survivor.stalled);
            let wait: Duration = survivor
                .spans
                .iter()
                .filter(|s| s.kind == SpanKind::Wait)
                .map(|s| s.end - s.start)
                .sum();
            assert!(wait >= Duration::from_secs(300), "waited {wait:?}");
        }
        assert!(!nodes[2].stalled);
        assert_eq!(nodes[2].finish, ms(230), "crashed at round 0's completion");
    }

    #[test]
    fn partial_participation_trains_only_the_cohort() {
        let mut spec =
            TrialSpec::new(FederationMode::Async, vec![ms(10); 20], 4);
        spec.participation = 0.25;
        let nodes = run_events_trial(&spec).unwrap();
        let plan = ParticipationPlan::new(0.25, AvailabilitySpec::None, spec.seed, 20);
        for node in &nodes {
            let rounds_in: usize =
                (0..4).filter(|&r| plan.participates(node.node_id, r)).count();
            let trained =
                node.spans.iter().filter(|s| s.kind == SpanKind::Train).count();
            assert_eq!(trained, rounds_in, "node {} trains cohort rounds only", node.node_id);
            assert_eq!(node.finish, ms(10) * rounds_in as u32, "skips cost zero time");
        }
        let total: usize = nodes
            .iter()
            .map(|n| n.spans.iter().filter(|s| s.kind == SpanKind::Train).count())
            .sum();
        assert_eq!(total, 4 * 5, "4 rounds × cohort of 5");
    }

    #[test]
    fn churn_trace_replays_bit_identically() {
        let mk = || {
            let mut spec = TrialSpec::new(
                FederationMode::Async,
                (0..12).map(|i| ms(20 + i)).collect(),
                5,
            );
            spec.availability = AvailabilitySpec::Churn { p: 0.3 };
            spec.seed = 1234;
            run_events_trial(&spec).unwrap()
        };
        let a = mk();
        let b = mk();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.finish, y.finish);
            assert_eq!(x.spans, y.spans, "node {}", x.node_id);
            assert_eq!(x.params.0, y.params.0);
            assert_eq!(x.stalled, y.stalled);
        }
    }
}
