//! Kernel-layer determinism suite — the contract that makes `threads` a
//! pure wall-clock knob: every pooled kernel (fused weighted average,
//! axpy/lerp, each codec's encode/decode, the chunked content hash)
//! must produce **bit-identical** results for `threads = 1` and
//! `threads = 8`, wire blobs must not change by a byte, and a golden
//! sweep report under `threads = 4` + the virtual clock must show
//! simulated timings unchanged by parallelism.
//!
//! Everything here is artifact-free (no PJRT runtime needed).

use std::sync::Arc;
use std::time::Duration;

use fedless::compress::{Codec, CodecKind, CodecState};
use fedless::config::{ClockKind, ExperimentConfig, FederationMode};
use fedless::metrics::timeline::Timeline;
use fedless::par::ChunkPool;
use fedless::protocol::ProtocolKind;
use fedless::store::{MemoryStore, WeightStore};
use fedless::strategy::StrategyKind;
use fedless::tensor::codec::{encode_blob, raw_wire_bytes, BlobMeta};
use fedless::tensor::flat::{weighted_average_pooled, FlatParams, PAR_CHUNK};
use fedless::time::{Clock, ParticipantGuard, VirtualClock};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn training_like(n: usize, seed: u64) -> FlatParams {
    FlatParams(
        (0..n)
            .map(|i| ((i as f32) * 0.0137 + seed as f32 * 0.11).sin() * 0.8)
            .collect(),
    )
}

const THREADS: [usize; 3] = [1, 2, 8];

// ---------------------------------------------------------------------------
// kernel-level bit-identity

#[test]
fn weighted_average_is_bit_identical_across_thread_counts() {
    // ragged sizes straddling chunk boundaries; K from 1 to 6
    for n in [1usize, 1000, PAR_CHUNK, PAR_CHUNK + 1, 3 * PAR_CHUNK + 17] {
        for k in [1usize, 2, 6] {
            let clients: Vec<FlatParams> =
                (0..k).map(|c| training_like(n, c as u64)).collect();
            let refs: Vec<&FlatParams> = clients.iter().collect();
            let w: Vec<f32> = (1..=k).map(|i| i as f32 / (k * (k + 1) / 2) as f32).collect();
            let reference = weighted_average_pooled(&refs, &w, ChunkPool::sequential());
            for t in THREADS {
                let out = weighted_average_pooled(&refs, &w, ChunkPool::new(t));
                assert_eq!(bits(&out.0), bits(&reference.0), "n={n} k={k} threads={t}");
            }
        }
    }
}

#[test]
fn every_codec_round_trip_is_bit_identical_across_thread_counts() {
    let n = 2 * PAR_CHUNK + 300;
    let p = training_like(n, 3);
    let base = training_like(n, 4);
    for kind in [
        CodecKind::None,
        CodecKind::Q8,
        CodecKind::TopK { frac: 0.1 },
        CodecKind::TopK { frac: 1.0 },
        CodecKind::DeltaQ8,
    ] {
        let codec = kind.build();
        let b = Some(&base);
        let enc_ref = codec.encode_pooled(&p, b, ChunkPool::sequential());
        let dec_ref = codec.decode_pooled(&enc_ref, n, b, ChunkPool::sequential()).unwrap();
        for t in THREADS {
            let pool = ChunkPool::new(t);
            assert_eq!(
                codec.encode_pooled(&p, b, pool),
                enc_ref,
                "{}: payload bytes must not depend on threads={t}",
                kind.label()
            );
            let dec = codec.decode_pooled(&enc_ref, n, b, pool).unwrap();
            assert_eq!(
                bits(&dec.0),
                bits(&dec_ref.0),
                "{}: reconstruction must not depend on threads={t}",
                kind.label()
            );
        }
    }
}

#[test]
fn chunked_hash_is_bit_identical_across_thread_counts() {
    use fedless::util::hash::chunked_hash_f32s_pooled;
    for n in [0usize, 7, PAR_CHUNK, 5 * PAR_CHUNK + 3] {
        let p = training_like(n, 9);
        let reference = chunked_hash_f32s_pooled(p.as_slice(), ChunkPool::sequential());
        for t in THREADS {
            assert_eq!(
                chunked_hash_f32s_pooled(p.as_slice(), ChunkPool::new(t)),
                reference,
                "n={n} threads={t}"
            );
        }
        assert_eq!(p.content_hash(), reference, "content_hash is the chunked hash");
        assert_eq!(p.content_hash_pooled(ChunkPool::new(8)), reference);
    }
}

// ---------------------------------------------------------------------------
// wire-format stability

/// `compress = none` under any thread count keeps today's v1 blob
/// byte-for-byte, and codec pushes keep their v2 blobs byte-for-byte —
/// the on-disk/wire compatibility half of the determinism contract.
#[test]
fn wire_blobs_are_unchanged_by_the_thread_count() {
    let meta = BlobMeta { node_id: 2, round: 5, epoch: 5, n_examples: 640 };
    let p = training_like(4_096, 1);
    let state = CodecState::new(CodecKind::None);
    for t in THREADS {
        let (wire, stored) = state.encode_for_push(&meta, &p, ChunkPool::new(t)).unwrap();
        assert_eq!(wire, encode_blob(&meta, &p).len() as u64, "v1 blob size, threads={t}");
        assert_eq!(wire, raw_wire_bytes(p.len()));
        assert_eq!(bits(&stored.0), bits(&p.0), "v1 path is bit-exact, threads={t}");
    }
    for kind in [CodecKind::Q8, CodecKind::TopK { frac: 0.1 }, CodecKind::DeltaQ8] {
        let reference = CodecState::new(kind)
            .encode_for_push(&meta, &p, ChunkPool::sequential())
            .unwrap();
        for t in THREADS {
            let state = CodecState::new(kind);
            let (wire, stored) = state.encode_for_push(&meta, &p, ChunkPool::new(t)).unwrap();
            assert_eq!(wire, reference.0, "{} v2 wire bytes, threads={t}", kind.label());
            assert_eq!(
                bits(&stored.0),
                bits(&reference.1 .0),
                "{} reconstruction, threads={t}",
                kind.label()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// protocol-level: a full federation replays bit-identically across
// thread counts, and simulated timings don't move

/// What one simulated node reports back.
struct SimNode {
    finish: Duration,
    params: FlatParams,
}

/// Drive a 3-node federation on a virtual clock, with every kernel on a
/// `threads`-wide pool (codec via `EpochCtx.pool`, aggregation
/// via `EpochCtx.pool`) — the same harness shape as `tests/timing.rs`,
/// plus compression so the parallel codec path is actually exercised.
fn run_sim(mode: FederationMode, threads: usize, epochs: usize) -> Vec<SimNode> {
    const N: usize = 3;
    let clock: Arc<dyn Clock> = Arc::new(VirtualClock::new());
    let cfg = ExperimentConfig {
        mode,
        n_nodes: N,
        compress: CodecKind::Q8,
        threads,
        ..Default::default()
    };
    let store: Arc<dyn WeightStore> = Arc::new(MemoryStore::with_clock(Arc::clone(&clock)));
    for _ in 0..N {
        clock.enter();
    }
    let start = Arc::new(std::sync::Barrier::new(N));
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..N)
            .map(|node_id| {
                let clock = Arc::clone(&clock);
                let store = Arc::clone(&store);
                let cfg = cfg.clone();
                let start = Arc::clone(&start);
                scope.spawn(move || {
                    let _p = ParticipantGuard::adopt(Arc::clone(&clock));
                    let pool = ChunkPool::from_config(cfg.threads);
                    let mut protocol = ProtocolKind::from(cfg.mode).build(node_id, &cfg);
                    let mut strategy = StrategyKind::FedAvg.build();
                    let mut codec = CodecState::new(cfg.compress);
                    let mut timeline = Timeline::new(node_id);
                    let mut params = training_like(PAR_CHUNK + 37, node_id as u64);
                    start.wait();
                    for epoch in 0..epochs {
                        // distinct per-node "training" so no two events
                        // share a simulated instant
                        clock.sleep(Duration::from_millis(40 + 9 * node_id as u64));
                        let mut ctx = fedless::protocol::EpochCtx {
                            node_id,
                            n_nodes: N,
                            round_k: N,
                            epoch,
                            n_examples: 100,
                            store: store.as_ref(),
                            strategy: strategy.as_mut(),
                            timeline: &mut timeline,
                            sync_timeout: Duration::from_secs(3600),
                            clock: clock.as_ref(),
                            codec: &mut codec,
                            pool,
                            tracer: None,
                        };
                        protocol.after_epoch(&mut ctx, &mut params).unwrap();
                    }
                    SimNode { finish: clock.now(), params }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// The whole-federation determinism claim: weights AND simulated
/// finish times are bit-identical whether the kernels run on 1 or 8
/// threads (compute takes zero simulated time regardless of `threads`).
#[test]
fn federation_replays_bit_identically_across_thread_counts() {
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let reference = run_sim(mode, 1, 4);
        for t in [4usize, 8] {
            let run = run_sim(mode, t, 4);
            for (a, b) in reference.iter().zip(&run) {
                assert_eq!(
                    a.finish, b.finish,
                    "{mode:?}: simulated timing must not move with threads={t}"
                );
                assert_eq!(
                    bits(&a.params.0),
                    bits(&b.params.0),
                    "{mode:?}: weights must be bit-identical with threads={t}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// executor-vs-threads conformance with compressed pushes

/// The event executor replays the threaded Q8 federation bit-for-bit:
/// same finish instants, same weights, same content digests — the
/// global-digest half of the scheduler-conformance contract
/// (`rust/tests/timing.rs` pins the timeline half).
#[test]
fn event_executor_matches_threads_under_q8_compression() {
    use fedless::sched::{run_events_trial, TrialSpec};

    for mode in [FederationMode::Sync, FederationMode::Async] {
        let threaded = run_sim(mode, 1, 4);
        let mut spec = TrialSpec::new(
            mode,
            (0..3).map(|i| Duration::from_millis(40 + 9 * i)).collect(),
            4,
        );
        spec.compress = CodecKind::Q8;
        spec.init = |node_id| training_like(PAR_CHUNK + 37, node_id as u64);
        let events = run_events_trial(&spec).unwrap();
        for (t, e) in threaded.iter().zip(&events) {
            assert_eq!(t.finish, e.finish, "{mode:?}: node {} finish", e.node_id);
            assert_eq!(
                bits(&t.params.0),
                bits(&e.params.0),
                "{mode:?}: node {} weights",
                e.node_id
            );
            assert_eq!(
                t.params.content_hash(),
                e.params.content_hash(),
                "{mode:?}: node {} digest",
                e.node_id
            );
        }
    }
}

// ---------------------------------------------------------------------------
// golden sweep report under threads = 4 + virtual clock

/// A tiny mode × threads sweep whose trial runner simulates the
/// protocols on a fresh virtual clock per trial: the rendered report —
/// including the wall-clock column — must match a golden snapshot, and
/// the `threads = 4` rows must carry exactly the same simulated timings
/// as `threads = 1` (parallelism is invisible to simulated time).
#[test]
fn golden_sweep_report_with_threads_axis_under_virtual_clock() {
    use fedless::sweep::{run_sweep_with, SweepSpec};

    let base = ExperimentConfig {
        clock: ClockKind::Virtual,
        n_nodes: 3,
        epochs: 3,
        seed: 42,
        ..Default::default()
    };
    let mut spec = SweepSpec::from_base(base);
    spec.modes = vec![FederationMode::Sync, FederationMode::Async];
    spec.threads = vec![1, 4];
    spec.seeds = vec![42, 43];
    spec.jobs = 1;

    let runner = |cfg: &ExperimentConfig| -> anyhow::Result<fedless::sim::ExperimentResult> {
        let nodes = run_sim(cfg.mode, cfg.threads, cfg.epochs);
        let wall = nodes.iter().map(|n| n.finish).max().unwrap();
        Ok(fedless::sim::ExperimentResult {
            // deterministic stand-in metrics; exact *timing* is the point
            final_accuracy: 0.9 - if cfg.mode == FederationMode::Async { 0.02 } else { 0.0 },
            final_loss: 0.1,
            wall_clock_s: wall.as_secs_f64(),
            reports: vec![],
            global_hash: 0,
            store_pushes: 0,
            mean_idle_fraction: 0.0,
            all_completed: true,
            divergence: None,
            trace_dir: None,
        })
    };

    let body = |md: &str| -> String {
        // skip the header line: it carries the sweep's *real* wall-clock
        md.lines().skip(1).collect::<Vec<_>>().join("\n")
    };

    let r1 = run_sweep_with(&spec, runner).unwrap();
    let r2 = run_sweep_with(&spec, runner).unwrap();
    assert_eq!(r1.n_failures, 0, "{}", r1.to_markdown());
    assert_eq!(body(&r1.to_markdown()), body(&r2.to_markdown()), "must replay identically");

    // sync: every epoch ends at the straggler's pace (40 + 9·2 = 58 ms);
    // async: the slowest node still finishes at 3 × 58 ms = 174 ms.
    // Identical numbers in the t=1 and t=4 rows ARE the proof that
    // parallel kernels leave simulated time untouched.
    let golden = "\n\
| mode | strategy | skew | nodes | compress | threads | part | adversary | trials | accuracy (mean ± std) | acc clean | acc attacked | loss (mean ± std) | wall-clock s | MB pushed | MB pulled |\n\
|------|----------|------|-------|----------|---------|------|-----------|--------|-----------------------|-----------|--------------|-------------------|--------------|-----------|-----------|\n\
| sync | fedavg | 0 | 3 | none | 1 | 1 | none | 2 | 0.900 ± 0.000 | 0.900 | - | 0.100 ± 0.000 | 0.174 ± 0.000 | 0.00 | 0.00 |\n\
| sync | fedavg | 0 | 3 | none | 4 | 1 | none | 2 | 0.900 ± 0.000 | 0.900 | - | 0.100 ± 0.000 | 0.174 ± 0.000 | 0.00 | 0.00 |\n\
| async | fedavg | 0 | 3 | none | 1 | 1 | none | 2 | 0.880 ± 0.000 | 0.880 | - | 0.100 ± 0.000 | 0.174 ± 0.000 | 0.00 | 0.00 |\n\
| async | fedavg | 0 | 3 | none | 4 | 1 | none | 2 | 0.880 ± 0.000 | 0.880 | - | 0.100 ± 0.000 | 0.174 ± 0.000 | 0.00 | 0.00 |";
    assert_eq!(
        body(&r1.to_markdown()),
        golden,
        "sweep body diverged from the golden snapshot:\n{}",
        r1.to_markdown()
    );
}
