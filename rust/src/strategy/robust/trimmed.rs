//! [`TrimmedMean`] — coordinate-wise trimmed mean aggregation.

use crate::par::ChunkPool;
use crate::tensor::FlatParams;

use super::super::{Contribution, Strategy};
use super::{by_node, per_coordinate};

/// Coordinate-wise trimmed mean: per coordinate, sort the n client
/// values, drop the `⌊frac·n⌋` smallest and largest, and average what
/// remains (uniformly — see the module note on `n_examples`). Robust to
/// up to `⌊frac·n⌋` arbitrary vectors; `frac = 0` degrades to a plain
/// uniform mean.
#[derive(Clone, Copy, Debug)]
pub struct TrimmedMean {
    frac: f64,
}

impl TrimmedMean {
    /// Trim fraction per tail; clamped into `[0, 0.5)`.
    pub fn new(frac: f64) -> Self {
        TrimmedMean { frac: frac.clamp(0.0, 0.4999) }
    }

    /// The configured per-tail trim fraction.
    pub fn frac(&self) -> f64 {
        self.frac
    }
}

impl Strategy for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed-mean"
    }

    fn aggregate_pooled(
        &mut self,
        contribs: &[Contribution],
        pool: ChunkPool,
    ) -> Option<FlatParams> {
        if contribs.is_empty() {
            return None;
        }
        let sorted = by_node(contribs);
        let m = sorted.len();
        // keep at least one value: never trim past the central element(s)
        let k = ((self.frac * m as f64).floor() as usize).min((m - 1) / 2);
        Some(per_coordinate(&sorted, pool, |col| {
            let kept = &col[k..m - k];
            let mut acc = 0.0f64;
            for v in kept {
                acc += *v as f64;
            }
            (acc / kept.len() as f64) as f32
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::strategy_tests::contrib;
    use super::*;

    #[test]
    fn trims_extremes_per_coordinate() {
        let cs = [
            contrib(0, 100, true, &[0.0]),
            contrib(1, 100, false, &[2.0]),
            contrib(2, 100, false, &[4.0]),
            contrib(3, 100, false, &[1e9]),
        ];
        // n=4, frac=0.25 -> drop 1 per tail, average the central pair
        let out = TrimmedMean::new(0.25).aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![3.0]);
    }

    #[test]
    fn zero_frac_is_uniform_mean() {
        let cs = [contrib(0, 100, true, &[1.0]), contrib(1, 100, false, &[3.0])];
        let out = TrimmedMean::new(0.0).aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![2.0]);
    }

    #[test]
    fn trim_never_empties_the_column() {
        // frac near 0.5 on a tiny cohort still keeps the central element
        let cs = [
            contrib(0, 100, true, &[1.0]),
            contrib(1, 100, false, &[5.0]),
            contrib(2, 100, false, &[9.0]),
        ];
        let out = TrimmedMean::new(0.49).aggregate(&cs).unwrap();
        assert_eq!(out.0, vec![5.0]);
    }
}
