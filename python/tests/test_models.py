"""L2 model zoo: shapes, determinism, gradient flow, and pallas/jnp parity
of the full forward pass for every registered model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import train as T
from compile.models import common as mc
from compile.models import get_model

SMALL_MODELS = ["mnist", "cifar", "lm"]


@pytest.fixture(autouse=True)
def _reset_pallas_flag():
    yield
    mc.set_pallas_dense(False)


def _batch(spec, seed=0):
    r = np.random.default_rng(seed)
    b = spec.batch_size
    if spec.input_dtype == "i32":
        x = r.integers(0, spec.num_classes, (b, *spec.input_shape)).astype(np.int32)
    else:
        x = r.standard_normal((b, *spec.input_shape)).astype(np.float32)
    y = r.integers(0, spec.num_classes, (b,)).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_init_is_deterministic(name):
    spec = get_model(name)
    init = T.make_init_step(spec)
    seed = jnp.asarray([0, 42], jnp.uint32)
    (a,) = init(seed)
    (b,) = init(seed)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    (c,) = init(jnp.asarray([0, 43], jnp.uint32))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_param_count_positive_and_stable(name):
    spec = get_model(name)
    p = T.param_count(spec)
    assert p > 1000
    (flat,) = T.make_init_step(spec)(jnp.asarray([0, 1], jnp.uint32))
    assert flat.shape == (p,)
    assert bool(jnp.all(jnp.isfinite(flat)))


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_train_step_shapes_and_finiteness(name):
    spec = get_model(name)
    p = T.param_count(spec)
    (flat,) = T.make_init_step(spec)(jnp.asarray([0, 1], jnp.uint32))
    m = jnp.zeros((p,), jnp.float32)
    v = jnp.zeros((p,), jnp.float32)
    x, y = _batch(spec)
    step = T.make_train_step(spec, use_pallas=False)
    f2, m2, v2, s2, loss, acc = step(flat, m, v, jnp.asarray(0, jnp.int32), x, y)
    assert f2.shape == (p,) and m2.shape == (p,) and v2.shape == (p,)
    assert int(s2) == 1
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    assert 0 <= float(acc) <= y.size if name != "lm" else True
    assert bool(jnp.all(jnp.isfinite(f2)))
    # parameters must actually move
    assert float(jnp.max(jnp.abs(f2 - flat))) > 0


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_loss_decreases_over_repeated_steps(name):
    """Overfit a single batch for a few steps: loss must drop."""
    spec = get_model(name)
    p = T.param_count(spec)
    (flat,) = T.make_init_step(spec)(jnp.asarray([0, 7], jnp.uint32))
    m = jnp.zeros((p,), jnp.float32)
    v = jnp.zeros((p,), jnp.float32)
    s = jnp.asarray(0, jnp.int32)
    x, y = _batch(spec, seed=5)
    step = jax.jit(T.make_train_step(spec, use_pallas=False))
    losses = []
    for _ in range(8):
        flat, m, v, s, loss, _ = step(flat, m, v, s, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", SMALL_MODELS)
def test_eval_step_counts(name):
    spec = get_model(name)
    (flat,) = T.make_init_step(spec)(jnp.asarray([0, 1], jnp.uint32))
    x, y = _batch(spec)
    loss, correct = T.make_eval_step(spec, use_pallas=False)(flat, x, y)
    n_preds = y.size if spec.input_dtype == "f32" else y.size * (spec.input_shape[0] - 1)
    assert 0 <= float(correct) <= n_preds
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("name", ["mnist", "lm"])
def test_pallas_vs_jnp_train_step_parity(name):
    """The full train step must agree between kernel and oracle paths."""
    spec = get_model(name)
    p = T.param_count(spec)
    (flat,) = T.make_init_step(spec)(jnp.asarray([0, 3], jnp.uint32))
    m = jnp.zeros((p,), jnp.float32)
    v = jnp.zeros((p,), jnp.float32)
    s = jnp.asarray(0, jnp.int32)
    x, y = _batch(spec, seed=9)
    ref = T.make_train_step(spec, use_pallas=False)(flat, m, v, s, x, y)
    pal = T.make_train_step(spec, use_pallas=True)(flat, m, v, s, x, y)
    for a, b in zip(pal, ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5
        )


def test_lm_config_registry():
    from compile.models.lm import CONFIGS

    assert set(CONFIGS) >= {"lm", "lm_medium", "lm14m"}
    spec14 = get_model("lm14m")
    # Pythia-14M budget: d=512 L=6 -> ~19-20M with embeddings at vocab=256
    p = T.param_count(spec14)
    assert 10_000_000 < p < 30_000_000


def test_unknown_model_raises():
    with pytest.raises(KeyError):
        get_model("nope")
