//! Binary blob codec for weight-store entries (the wire/disk format).
//!
//! Two format versions coexist:
//!
//! **v1** (raw f32, the original format — still written by [`FsStore`]
//! and by `compress = none` pushes, still decoded everywhere):
//! ```text
//!   magic   u32   0x464C_5752  ("FLWR")
//!   version u16   1
//!   flags   u16   reserved, 0
//!   node_id u32
//!   round   u64   (sync round; async entries use the node's epoch counter)
//!   epoch   u64
//!   n_examples u64
//!   len     u64   number of f32 elements
//!   hash    u64   fnv1a64 of the payload bytes
//!   payload len * 4 bytes of f32 LE
//! ```
//!
//! **v2** (codec-encoded, produced by the [`crate::compress`] layer):
//! ```text
//!   magic        u32   0x464C_5752  ("FLWR")
//!   version      u16   2
//!   flags        u16   reserved, 0
//!   node_id      u32
//!   round        u64
//!   epoch        u64
//!   n_examples   u64
//!   codec        u16   codec id (crate::compress::CodecKind::id)
//!   reserved     u16   0
//!   base_version u64   base entry the payload deltas against (0 = none)
//!   uncomp_len   u64   decoded element count (f32 elements)
//!   enc_len      u64   encoded payload length in bytes
//!   hash         u64   fnv1a64 of the whole blob with this field zeroed
//!   payload      enc_len bytes (codec-specific)
//! ```
//!
//! The v1 hash covers the payload only — enough to catch torn writes in
//! [`FsStore`], the failure mode it was built for. The v2 hash covers
//! header *and* payload (with the hash field itself zeroed), so any
//! single corrupted byte anywhere in a v2 blob yields a clean decode
//! error — never a silently wrong metadata field (exhaustively checked
//! by the single-byte corruption sweep in this module's tests).
//!
//! [`FsStore`]: crate::store::FsStore

use anyhow::{bail, Result};

use super::FlatParams;
use crate::util::fnv1a64;
use crate::util::hash::fnv1a64_multi;

/// Blob magic number ("FLWR" little-endian).
pub const MAGIC: u32 = 0x464C_5752;
/// Raw-f32 blob format version.
pub const VERSION: u16 = 1;
/// Codec-encoded blob format version.
pub const VERSION_V2: u16 = 2;
/// Fixed v1 header size in bytes (everything before the payload).
pub const HEADER_LEN: usize = 4 + 2 + 2 + 4 + 8 + 8 + 8 + 8 + 8;
/// Fixed v2 header size in bytes (everything before the payload).
pub const HEADER_LEN_V2: usize = 4 + 2 + 2 + 4 + 8 + 8 + 8 + 2 + 2 + 8 + 8 + 8 + 8;

/// Wire size in bytes of an *uncompressed* (v1) entry of `n` f32
/// elements, header included — what every push cost before the
/// compression layer existed, and still the `compress = none` wire cost.
pub fn raw_wire_bytes(n: usize) -> u64 {
    (HEADER_LEN + n * 4) as u64
}

/// Largest element count a blob header may claim (2^28 ≈ 268M f32, ~1 GB
/// raw — an order of magnitude above the biggest model here). Headers
/// beyond it are rejected before any decode buffer is allocated from
/// them; codecs whose payload size doesn't determine `n` (e.g. the topk
/// sparsifier) enforce the same ceiling on their own decode path.
pub const MAX_DECODE_ELEMS: usize = 1 << 28;

/// Metadata attached to a serialized weight entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlobMeta {
    /// Id of the node that produced the weights.
    pub node_id: u32,
    /// Sync round (async entries use the node's epoch counter).
    pub round: u64,
    /// The producing node's local epoch counter.
    pub epoch: u64,
    /// Examples the node trained on (FedAvg numerator n_k).
    pub n_examples: u64,
}

/// A parsed, integrity-checked blob of either version, with the payload
/// still encoded. v1 blobs parse as `codec_id = 0` (raw) with the f32
/// bytes as payload; materialize params with [`decode_blob`] (raw) or
/// `crate::compress::CodecState::decode_wire` (any codec).
///
/// The payload **borrows** the wire buffer ([`read_blob`] is zero-copy):
/// parsing a pulled blob allocates nothing, and the raw-codec decode
/// path can view the payload as `&[f32]` in place ([`view_raw_payload`])
/// so a whole pull costs at most the one allocation that materializes
/// the `FlatParams`.
#[derive(Clone, Debug, PartialEq)]
pub struct WireBlob<'a> {
    /// Entry metadata from the header.
    pub meta: BlobMeta,
    /// Which codec encoded the payload (`crate::compress::CodecKind::id`);
    /// 0 = raw f32.
    pub codec_id: u16,
    /// Base entry version the payload deltas against (0 = self-contained).
    pub base_version: u64,
    /// Decoded element count.
    pub uncomp_len: usize,
    /// The encoded payload bytes, borrowed from the wire buffer.
    pub payload: &'a [u8],
}

/// Append `xs` to `out` as little-endian f32 bytes in one bulk slab
/// write (the write-side twin of [`view_raw_payload`]). On little-endian
/// hosts this is a single `memcpy`; elsewhere it falls back to the
/// per-element loop it replaced, so the produced bytes are identical
/// everywhere (pinned by the wire test suite's byte-for-byte regression
/// against the old loop).
pub fn extend_f32s_le(out: &mut Vec<u8>, xs: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: any f32 is plain old data; on a little-endian host its
        // in-memory bytes are exactly its `to_le_bytes` serialization.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        out.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// A decoded f32 view over payload bytes: borrowed straight from the
/// wire buffer when the platform and alignment allow, copied otherwise.
/// Both forms hold bit-identical element values; only the allocation
/// count differs (pinned by the unaligned-buffer wire tests).
#[derive(Debug)]
pub enum F32View<'a> {
    /// An aligned little-endian view into the wire buffer (zero-copy).
    Borrowed(&'a [f32]),
    /// A materialized copy (misaligned buffer or big-endian host).
    Owned(Vec<f32>),
}

impl std::ops::Deref for F32View<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        match self {
            F32View::Borrowed(s) => s,
            F32View::Owned(v) => v,
        }
    }
}

impl F32View<'_> {
    /// True when this view borrows the wire buffer (no copy was made).
    pub fn is_borrowed(&self) -> bool {
        matches!(self, F32View::Borrowed(_))
    }

    /// Materialize as owned params (the view's only allocation when it
    /// was borrowed; free when it already owns the copy).
    pub fn into_params(self) -> FlatParams {
        FlatParams(match self {
            F32View::Borrowed(s) => s.to_vec(),
            F32View::Owned(v) => v,
        })
    }
}

/// View raw f32 payload bytes without copying when possible: on a
/// little-endian host with a 4-byte-aligned payload this is a pointer
/// cast (the bytemuck-style checked cast); otherwise the bytes are
/// bulk-copied once. Length is validated against `uncomp_len` first,
/// exactly like [`decode_raw_payload`].
pub fn view_raw_payload(payload: &[u8], uncomp_len: usize) -> Result<F32View<'_>> {
    let expect = uncomp_len
        .checked_mul(4)
        .filter(|&b| b == payload.len())
        .is_some();
    if !expect {
        bail!("raw payload is {} bytes, want {} * 4", payload.len(), uncomp_len);
    }
    #[cfg(target_endian = "little")]
    {
        // SAFETY (of the transmute inside align_to): every 4-byte
        // pattern is a valid f32; the prefix/suffix emptiness check
        // below is what guarantees the middle is 4-byte aligned and
        // covers the whole payload.
        let (prefix, mid, suffix) = unsafe { payload.align_to::<f32>() };
        if prefix.is_empty() && suffix.is_empty() {
            return Ok(F32View::Borrowed(mid));
        }
    }
    let mut xs = vec![0.0f32; uncomp_len];
    #[cfg(target_endian = "little")]
    // SAFETY: dst spans exactly uncomp_len * 4 == payload.len() bytes,
    // and a bulk byte copy of LE bytes into f32 storage is exactly
    // per-element from_le_bytes on this endianness.
    unsafe {
        std::ptr::copy_nonoverlapping(
            payload.as_ptr(),
            xs.as_mut_ptr() as *mut u8,
            payload.len(),
        );
    }
    #[cfg(not(target_endian = "little"))]
    for (x, chunk) in xs.iter_mut().zip(payload.chunks_exact(4)) {
        *x = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(F32View::Owned(xs))
}

fn push_common_header(out: &mut Vec<u8>, version: u16, meta: &BlobMeta) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&meta.node_id.to_le_bytes());
    out.extend_from_slice(&meta.round.to_le_bytes());
    out.extend_from_slice(&meta.epoch.to_le_bytes());
    out.extend_from_slice(&meta.n_examples.to_le_bytes());
}

/// Serialize params + metadata into a self-validating v1 (raw f32) blob.
pub fn encode_blob(meta: &BlobMeta, params: &FlatParams) -> Vec<u8> {
    let payload_len = params.len() * 4;
    let mut out = Vec::with_capacity(HEADER_LEN + payload_len);
    push_common_header(&mut out, VERSION, meta);
    out.extend_from_slice(&(params.len() as u64).to_le_bytes());
    // hash goes after len; fill payload first, then patch
    let hash_pos = out.len();
    out.extend_from_slice(&0u64.to_le_bytes());
    extend_f32s_le(&mut out, params.as_slice());
    let h = fnv1a64(&out[HEADER_LEN..]);
    out[hash_pos..hash_pos + 8].copy_from_slice(&h.to_le_bytes());
    out
}

/// Serialize a codec-encoded payload into a self-validating v2 blob.
///
/// `codec_id` names the payload encoding (see
/// `crate::compress::CodecKind::id`), `base_version` the entry the
/// payload deltas against (0 = none), `uncomp_len` the decoded element
/// count. The hash covers the whole blob (hash field zeroed), so any
/// single-byte corruption is detected at [`read_blob`] time.
pub fn encode_blob_v2(
    meta: &BlobMeta,
    codec_id: u16,
    base_version: u64,
    uncomp_len: usize,
    payload: &[u8],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN_V2 + payload.len());
    push_common_header(&mut out, VERSION_V2, meta);
    out.extend_from_slice(&codec_id.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&base_version.to_le_bytes());
    out.extend_from_slice(&(uncomp_len as u64).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    let hash_pos = out.len();
    out.extend_from_slice(&0u64.to_le_bytes());
    out.extend_from_slice(payload);
    let h = fnv1a64(&out); // hash field is still zeroed here
    out[hash_pos..hash_pos + 8].copy_from_slice(&h.to_le_bytes());
    out
}

fn read_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes(b[at..at + 2].try_into().unwrap())
}
fn read_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}
fn read_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

fn read_meta(bytes: &[u8]) -> BlobMeta {
    BlobMeta {
        node_id: read_u32(bytes, 8),
        round: read_u64(bytes, 12),
        epoch: read_u64(bytes, 20),
        n_examples: read_u64(bytes, 28),
    }
}

/// Parse and integrity-check a blob of either version without decoding
/// — or copying — the payload (the returned [`WireBlob`] borrows
/// `bytes`). All header-supplied lengths are validated against the
/// actual byte count *before* any allocation, so a corrupt header can
/// never request an absurd allocation.
pub fn read_blob(bytes: &[u8]) -> Result<WireBlob<'_>> {
    if bytes.len() < HEADER_LEN.min(HEADER_LEN_V2) {
        bail!("blob too short: {} bytes", bytes.len());
    }
    if read_u32(bytes, 0) != MAGIC {
        bail!("bad magic");
    }
    match read_u16(bytes, 4) {
        VERSION => {
            if bytes.len() < HEADER_LEN {
                bail!("v1 blob too short: {} bytes", bytes.len());
            }
            let len = read_u64(bytes, 36) as usize;
            let hash = read_u64(bytes, 44);
            let payload = &bytes[HEADER_LEN..];
            let expect = len
                .checked_mul(4)
                .filter(|&b| b == payload.len())
                .is_some();
            if !expect {
                bail!("payload length {} != {} * 4 (torn write?)", payload.len(), len);
            }
            if fnv1a64(payload) != hash {
                bail!("payload hash mismatch (corrupt or torn write)");
            }
            Ok(WireBlob {
                meta: read_meta(bytes),
                codec_id: 0,
                base_version: 0,
                uncomp_len: len,
                payload,
            })
        }
        VERSION_V2 => {
            if bytes.len() < HEADER_LEN_V2 {
                bail!("v2 blob too short: {} bytes", bytes.len());
            }
            let codec_id = read_u16(bytes, 36);
            let base_version = read_u64(bytes, 40);
            let uncomp_len = read_u64(bytes, 48);
            let enc_len = read_u64(bytes, 56) as usize;
            let hash = read_u64(bytes, 64);
            let payload = &bytes[HEADER_LEN_V2..];
            if payload.len() != enc_len {
                bail!(
                    "encoded length {} != payload bytes {} (torn write?)",
                    enc_len,
                    payload.len()
                );
            }
            // Reject absurd element counts before anything downstream
            // allocates a decode buffer from this header field.
            if uncomp_len > MAX_DECODE_ELEMS as u64 {
                bail!("implausible uncompressed length {uncomp_len}");
            }
            // The v2 hash covers the whole blob with the hash field
            // zeroed: header corruption is as detectable as payload
            // corruption.
            if fnv1a64_multi(&[&bytes[..64], &[0u8; 8], payload]) != hash {
                bail!("blob hash mismatch (corrupt or torn write)");
            }
            Ok(WireBlob {
                meta: read_meta(bytes),
                codec_id,
                base_version,
                uncomp_len: uncomp_len as usize,
                payload,
            })
        }
        other => bail!("unsupported blob version {other}"),
    }
}

/// Decode raw f32 payload bytes into params (shared by the v1 path and
/// the raw v2 codec): [`view_raw_payload`] materialized, so it costs one
/// bulk copy instead of the per-element loop it replaced.
pub fn decode_raw_payload(payload: &[u8], uncomp_len: usize) -> Result<FlatParams> {
    Ok(view_raw_payload(payload, uncomp_len)?.into_params())
}

/// Decode and validate a *self-contained* blob: v1, or v2 with the raw
/// codec. Codec-encoded v2 blobs (quantized/sparse/delta payloads) need
/// the [`crate::compress`] layer — use
/// `crate::compress::CodecState::decode_wire` for those.
pub fn decode_blob(bytes: &[u8]) -> Result<(BlobMeta, FlatParams)> {
    let wire = read_blob(bytes)?;
    if wire.codec_id != 0 {
        bail!(
            "blob payload uses codec id {} — decode via the compress layer",
            wire.codec_id
        );
    }
    let params = decode_raw_payload(wire.payload, wire.uncomp_len)?;
    Ok((wire.meta, params))
}

/// Bytes a header-only peek needs: covers the larger (v2) fixed header,
/// and is more than a whole minimal v1 blob — so reading
/// `min(file_len, PEEK_LEN)` always captures the full header of a valid
/// blob of either version.
pub const PEEK_LEN: usize = HEADER_LEN_V2;

/// Header fields recoverable without the payload (see
/// [`peek_blob_header`]). A peek is *not* integrity-checked — both blob
/// hashes cover the payload, which a peek deliberately never reads — so
/// use it only to decide *whether* to do a full [`read_blob`], never as
/// a substitute for one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlobPeek {
    /// Entry metadata from the header.
    pub meta: BlobMeta,
    /// Blob format version ([`VERSION`] or [`VERSION_V2`]).
    pub version: u16,
    /// Payload codec id (0 for v1 blobs).
    pub codec_id: u16,
}

/// Parse the fixed-size header prefix of a blob (the first
/// [`PEEK_LEN`]-or-fewer bytes of the file) without touching the
/// payload. This is what lets [`crate::store::FsStore`] poll a directory
/// for changes and filter entries by round with O(header) I/O per file
/// instead of full-blob reads.
pub fn peek_blob_header(prefix: &[u8]) -> Result<BlobPeek> {
    if prefix.len() < HEADER_LEN {
        bail!("blob prefix too short for a header: {} bytes", prefix.len());
    }
    if read_u32(prefix, 0) != MAGIC {
        bail!("bad magic");
    }
    match read_u16(prefix, 4) {
        VERSION => Ok(BlobPeek { meta: read_meta(prefix), version: VERSION, codec_id: 0 }),
        VERSION_V2 => {
            if prefix.len() < HEADER_LEN_V2 {
                bail!("blob prefix too short for a v2 header: {} bytes", prefix.len());
            }
            Ok(BlobPeek {
                meta: read_meta(prefix),
                version: VERSION_V2,
                codec_id: read_u16(prefix, 36),
            })
        }
        other => bail!("unsupported blob version {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> BlobMeta {
        BlobMeta { node_id: 3, round: 7, epoch: 2, n_examples: 38400 }
    }

    #[test]
    fn round_trip() {
        let p = FlatParams(vec![1.0, -2.5, f32::MIN_POSITIVE, 1e30]);
        let blob = encode_blob(&meta(), &p);
        let (m2, p2) = decode_blob(&blob).unwrap();
        assert_eq!(m2, meta());
        assert_eq!(p2, p);
    }

    #[test]
    fn empty_params_round_trip() {
        let p = FlatParams(vec![]);
        let (m2, p2) = decode_blob(&encode_blob(&meta(), &p)).unwrap();
        assert_eq!(m2, meta());
        assert!(p2.is_empty());
    }

    #[test]
    fn detects_truncation() {
        let blob = encode_blob(&meta(), &FlatParams(vec![1.0; 100]));
        assert!(decode_blob(&blob[..blob.len() - 4]).is_err());
        assert!(decode_blob(&blob[..10]).is_err());
    }

    #[test]
    fn detects_corruption() {
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0; 100]));
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        assert!(decode_blob(&blob).is_err());
    }

    #[test]
    fn detects_bad_magic_and_version() {
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0]));
        blob[0] = 0;
        assert!(decode_blob(&blob).is_err());
        let mut blob2 = encode_blob(&meta(), &FlatParams(vec![1.0]));
        blob2[4] = 99;
        assert!(decode_blob(&blob2).is_err());
    }

    #[test]
    fn v1_corrupt_length_is_a_clean_error_not_an_allocation() {
        // A header that claims ~2^62 elements used to hit `len * 4`
        // unchecked arithmetic and a Vec::with_capacity of that size.
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0; 4]));
        blob[36..44].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decode_blob(&blob).unwrap_err();
        assert!(format!("{err}").contains("payload length"), "{err}");
        // A large-but-not-overflowing claimed length is also rejected
        // before any allocation sized from the header.
        let mut blob = encode_blob(&meta(), &FlatParams(vec![1.0; 4]));
        blob[36..44].copy_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(decode_blob(&blob).is_err());
    }

    #[test]
    fn v2_round_trip_preserves_every_field() {
        let payload: Vec<u8> = (0..=255u8).collect();
        let blob = encode_blob_v2(&meta(), 3, 17, 512, &payload);
        assert_eq!(blob.len(), HEADER_LEN_V2 + payload.len());
        let wire = read_blob(&blob).unwrap();
        assert_eq!(wire.meta, meta());
        assert_eq!(wire.codec_id, 3);
        assert_eq!(wire.base_version, 17);
        assert_eq!(wire.uncomp_len, 512);
        assert_eq!(wire.payload, payload);
    }

    #[test]
    fn v1_blobs_parse_through_read_blob() {
        // v1 → v2-API compatibility: the old format reads as a raw-codec
        // WireBlob with identical metadata and payload bytes.
        let p = FlatParams(vec![4.25, -1.5, 0.0]);
        let blob = encode_blob(&meta(), &p);
        let wire = read_blob(&blob).unwrap();
        assert_eq!(wire.meta, meta());
        assert_eq!(wire.codec_id, 0);
        assert_eq!(wire.base_version, 0);
        assert_eq!(wire.uncomp_len, 3);
        assert_eq!(decode_raw_payload(&wire.payload, wire.uncomp_len).unwrap(), p);
    }

    #[test]
    fn v2_raw_blob_decodes_via_decode_blob() {
        // a v2 blob whose payload is plain f32 bytes (codec id 0) is
        // self-contained, so the v1 entry point accepts it
        let p = FlatParams(vec![1.0, 2.0]);
        let mut payload = Vec::new();
        for x in p.as_slice() {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let blob = encode_blob_v2(&meta(), 0, 0, p.len(), &payload);
        let (m2, p2) = decode_blob(&blob).unwrap();
        assert_eq!(m2, meta());
        assert_eq!(p2, p);
    }

    #[test]
    fn v2_codec_blob_is_rejected_by_decode_blob() {
        let blob = encode_blob_v2(&meta(), 1, 0, 8, &[0u8; 16]);
        let err = decode_blob(&blob).unwrap_err();
        assert!(format!("{err}").contains("compress layer"), "{err}");
        // ...but parses fine through the version-aware entry point
        assert!(read_blob(&blob).is_ok());
    }

    #[test]
    fn v2_single_byte_corruption_sweep_always_errors() {
        // Flip every byte of a small v2 blob, one at a time: every flip
        // must yield Err — never a panic, and never a silent decode with
        // wrong metadata (the v1 hash covered only the payload, so a
        // flipped node_id byte used to decode "successfully").
        let payload: Vec<u8> = vec![7, 8, 9, 10, 11];
        let blob = encode_blob_v2(&meta(), 2, 5, 40, &payload);
        let clean = read_blob(&blob).unwrap();
        for i in 0..blob.len() {
            for flip in [0xFFu8, 0x01] {
                let mut bad = blob.clone();
                bad[i] ^= flip;
                match read_blob(&bad) {
                    Err(_) => {}
                    Ok(decoded) => panic!(
                        "byte {i} flipped with {flip:#x} decoded silently: {decoded:?} vs {clean:?}"
                    ),
                }
            }
        }
    }

    #[test]
    fn v2_truncation_and_length_lies_error_cleanly() {
        let blob = encode_blob_v2(&meta(), 1, 0, 64, &[3u8; 64]);
        for cut in [0, 1, 10, HEADER_LEN_V2 - 1, HEADER_LEN_V2, blob.len() - 1] {
            assert!(read_blob(&blob[..cut]).is_err(), "cut at {cut}");
        }
        // a header claiming an absurd uncompressed length is rejected
        // even when the hash is recomputed to match (a hostile blob, not
        // just a torn one)
        let huge = (u32::MAX as u64 + 1).to_le_bytes();
        let mut bad = blob.clone();
        bad[48..56].copy_from_slice(&huge);
        bad[64..72].copy_from_slice(&0u64.to_le_bytes());
        let h = fnv1a64(&bad);
        bad[64..72].copy_from_slice(&h.to_le_bytes());
        let err = read_blob(&bad).unwrap_err();
        assert!(format!("{err}").contains("implausible"), "{err}");
    }

    #[test]
    fn peek_reads_both_versions_headers_only() {
        let p = FlatParams(vec![1.5, -2.0, 0.25]);
        let v1 = encode_blob(&meta(), &p);
        let peek = peek_blob_header(&v1[..PEEK_LEN.min(v1.len())]).unwrap();
        assert_eq!(peek.meta, meta());
        assert_eq!(peek.version, VERSION);
        assert_eq!(peek.codec_id, 0);

        let v2 = encode_blob_v2(&meta(), 3, 0, 8, &[1u8; 9]);
        let peek2 = peek_blob_header(&v2[..PEEK_LEN]).unwrap();
        assert_eq!(peek2.meta, meta());
        assert_eq!(peek2.version, VERSION_V2);
        assert_eq!(peek2.codec_id, 3);

        // a minimal v1 blob is itself shorter than PEEK_LEN and peeks fine
        let tiny = encode_blob(&meta(), &FlatParams(vec![]));
        assert!(tiny.len() < PEEK_LEN);
        assert_eq!(peek_blob_header(&tiny).unwrap().meta, meta());

        // junk and truncated prefixes error instead of parsing
        assert!(peek_blob_header(b"not a blob").is_err());
        assert!(peek_blob_header(&v2[..HEADER_LEN_V2 - 1]).is_err());
        let mut bad = v1.clone();
        bad[0] ^= 1;
        assert!(peek_blob_header(&bad).is_err());
    }

    #[test]
    fn read_blob_borrows_and_view_is_zero_copy_when_aligned() {
        let p = FlatParams((0..64).map(|i| i as f32 * 0.5).collect());
        let blob = encode_blob(&meta(), &p);
        let wire = read_blob(&blob).unwrap();
        // the payload is a slice of the input buffer, not a copy
        let blob_range = blob.as_ptr() as usize..blob.as_ptr() as usize + blob.len();
        assert!(blob_range.contains(&(wire.payload.as_ptr() as usize)));
        // Whether the view borrows depends on the buffer's base
        // alignment (controlled alignment cases are pinned in
        // rust/tests/wire.rs); the values must be right either way.
        let view = view_raw_payload(wire.payload, wire.uncomp_len).unwrap();
        assert_eq!(&*view, p.as_slice());
        assert_eq!(view.into_params(), p);
    }

    #[test]
    fn bulk_slab_write_matches_per_element_loop() {
        // byte-for-byte regression against the replaced loop, over
        // adversarial bit patterns (NaN payloads, -0.0, denormals, inf)
        let xs = [
            0.0f32,
            -0.0,
            1.0,
            f32::NAN,
            f32::from_bits(0x7F80_0001), // signaling-NaN pattern
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::from_bits(1), // denormal
            -3.25e-38,
        ];
        let mut bulk = vec![0xAAu8; 3]; // non-empty prefix must be preserved
        extend_f32s_le(&mut bulk, &xs);
        let mut reference = vec![0xAAu8; 3];
        for x in &xs {
            reference.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(bulk, reference);
        // and the whole v1 encode (which now uses the slab write)
        // matches a reference blob built with the old loop
        let p = FlatParams(xs.to_vec());
        let blob = encode_blob(&meta(), &p);
        let mut old = Vec::new();
        old.extend_from_slice(&blob[..HEADER_LEN]); // header unchanged
        for x in &xs {
            old.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(blob, old);
    }

    #[test]
    fn raw_wire_bytes_matches_encoded_size() {
        for n in [0usize, 1, 7, 1000] {
            let blob = encode_blob(&meta(), &FlatParams(vec![0.5; n]));
            assert_eq!(raw_wire_bytes(n), blob.len() as u64);
        }
    }
}
