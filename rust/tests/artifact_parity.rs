//! Cross-layer parity: the rust-native math must agree with the lowered
//! Pallas/JAX artifacts executed via PJRT. This is the boundary contract of
//! the whole three-layer design. Requires `make artifacts`.

use fedless::data::{DataSource, DatasetKind, Split, SynthDataset};
use fedless::runtime::{AggExecutor, Engine, Manifest, ModelBundle, TrainState};
use fedless::tensor::flat::weighted_average;
use fedless::tensor::FlatParams;
use fedless::util::Rng;

fn random_params(rng: &mut Rng, n: usize) -> FlatParams {
    FlatParams((0..n).map(|_| rng.normal_f32()).collect())
}

#[test]
fn agg_kernel_matches_rust_weighted_average() {
    let engine = Engine::new().unwrap();
    let manifest = Manifest::discover().unwrap();
    let mut rng = Rng::new(11);
    for &k in &[2usize, 3, 5] {
        let agg = AggExecutor::load(&engine, &manifest, k).unwrap();
        // one unpadded length and one multi-chunk length
        for n in [10_000usize, manifest.chunk + 777] {
            let params: Vec<FlatParams> =
                (0..k).map(|_| random_params(&mut rng, n)).collect();
            let refs: Vec<&FlatParams> = params.iter().collect();
            let mut w: Vec<f32> = (0..k).map(|_| rng.f32() + 0.1).collect();
            let total: f32 = w.iter().sum();
            w.iter_mut().for_each(|x| *x /= total);

            let via_kernel = agg.aggregate(&refs, &w).unwrap();
            let via_rust = weighted_average(&refs, &w);
            let diff = via_kernel.max_abs_diff(&via_rust);
            assert!(
                diff < 1e-5,
                "k={k} n={n}: kernel vs rust max diff {diff}"
            );
        }
    }
}

#[test]
fn init_is_deterministic_across_engines() {
    let manifest = Manifest::discover().unwrap();
    let info = manifest.model("mnist").unwrap();
    let e1 = Engine::new().unwrap();
    let b1 = ModelBundle::load(&e1, info).unwrap();
    let p1 = b1.init_params(123).unwrap();
    let e2 = Engine::new().unwrap();
    let b2 = ModelBundle::load(&e2, info).unwrap();
    let p2 = b2.init_params(123).unwrap();
    assert_eq!(p1, p2, "same seed, same params on independent engines");
    let p3 = b2.init_params(124).unwrap();
    assert!(p1.max_abs_diff(&p3) > 0.0);
    assert!(p1.all_finite());
    assert_eq!(p1.len(), info.param_count);
}

#[test]
fn train_step_and_run_steps_agree() {
    // the literal-resident epoch loop must compute exactly the same states
    // as the step-at-a-time host path
    let manifest = Manifest::discover().unwrap();
    let info = manifest.model("mnist").unwrap();
    let engine = Engine::new().unwrap();
    let bundle = ModelBundle::load(&engine, info).unwrap();

    let ds = std::sync::Arc::new(SynthDataset::new(DatasetKind::Mnist, 5, 500, 50));
    let make_loader = || {
        fedless::data::BatchLoader::new(
            DataSource::Image { ds: std::sync::Arc::clone(&ds), split: Split::Train },
            (0..500).collect(),
            info.batch_size,
            9,
        )
    };

    let p0 = bundle.init_params(42).unwrap();
    // path A: 3 x train_step
    let mut sa = TrainState::new(p0.clone());
    let mut la = make_loader();
    for _ in 0..3 {
        let b = la.next_batch();
        bundle.train_step(&mut sa, &b).unwrap();
    }
    // path B: run_steps(3)
    let mut sb = TrainState::new(p0);
    let mut lb = make_loader();
    bundle.run_steps(&mut sb, &mut lb, 3, |_, _| {}).unwrap();

    assert_eq!(sa.step, 3);
    assert_eq!(sb.step, 3);
    let diff = sa.params.max_abs_diff(&sb.params);
    assert!(diff == 0.0, "paths diverged by {diff}");
}

#[test]
fn train_loss_decreases_on_fixed_shard() {
    let manifest = Manifest::discover().unwrap();
    let info = manifest.model("mnist").unwrap();
    let engine = Engine::new().unwrap();
    let bundle = ModelBundle::load(&engine, info).unwrap();
    let ds = std::sync::Arc::new(SynthDataset::new(DatasetKind::Mnist, 6, 1000, 100));
    let mut loader = fedless::data::BatchLoader::new(
        DataSource::Image { ds, split: Split::Train },
        (0..1000).collect(),
        info.batch_size,
        10,
    );
    let mut state = TrainState::new(bundle.init_params(1).unwrap());
    let mut losses = Vec::new();
    bundle
        .run_steps(&mut state, &mut loader, 40, |_, m| losses.push(m.loss))
        .unwrap();
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[35..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(state.params.all_finite());
    assert!(state.step == 40);
}

#[test]
fn eval_counts_are_bounded_and_consistent() {
    let manifest = Manifest::discover().unwrap();
    let info = manifest.model("mnist").unwrap();
    let engine = Engine::new().unwrap();
    let bundle = ModelBundle::load(&engine, info).unwrap();
    let ds = std::sync::Arc::new(SynthDataset::new(DatasetKind::Mnist, 6, 100, 320));
    let loader = fedless::data::BatchLoader::new(
        DataSource::Image { ds, split: Split::Test },
        (0..320).collect(),
        info.batch_size,
        4,
    );
    let params = bundle.init_params(2).unwrap();
    let batches = loader.full_batches();
    let (loss, acc) = bundle.evaluate(&params, &batches).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    // untrained params ~ chance accuracy (10 classes)
    assert!(acc < 0.5, "untrained acc {acc}");

    // single batch path agrees with the aggregate path direction
    let (l0, c0) = bundle.eval_batch(&params, &batches[0]).unwrap();
    assert!(l0.is_finite());
    assert!(c0 >= 0.0 && c0 <= info.batch_size as f32);
}

#[test]
fn all_manifest_models_compile_and_init() {
    let manifest = Manifest::discover().unwrap();
    let engine = Engine::new().unwrap();
    for (name, info) in &manifest.models {
        // lm14m compile+init is heavier; still worth exercising weekly but
        // keep CI fast by skipping the biggest variant here.
        if name == "lm14m" {
            continue;
        }
        let bundle = ModelBundle::load(&engine, info)
            .unwrap_or_else(|e| panic!("compile {name}: {e}"));
        let p = bundle.init_params(7).unwrap();
        assert_eq!(p.len(), info.param_count, "{name}");
        assert!(p.all_finite(), "{name}");
    }
}
