"""AOT pipeline: manifest structure, HLO text validity, and the
build-products contract the rust Manifest parser depends on."""

import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import train as T
from compile.hlo import lower_fn
from compile.models import get_model

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_lower_fn_produces_parseable_hlo_text():
    spec = get_model("mnist")
    seed = jax.ShapeDtypeStruct((2,), jnp.uint32)
    text = lower_fn(T.make_init_step(spec), seed)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_lower_fn_keeps_unused_args():
    # the LM's train step ignores y; the artifact must still take it
    def f(a, b):
        return (a * 2.0,)

    a = jax.ShapeDtypeStruct((4,), jnp.float32)
    b = jax.ShapeDtypeStruct((4,), jnp.float32)
    text = lower_fn(f, a, b)
    # both parameters present in the entry computation
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(0)") == 1
    assert entry.count("parameter(1)") == 1


def test_train_step_artifact_is_tuple_of_six():
    spec = get_model("mnist")
    p = T.param_count(spec)
    fp = jax.ShapeDtypeStruct((p,), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    x, y = T.example_batch(spec)
    text = lower_fn(T.make_train_step(spec, use_pallas=False), fp, fp, fp, step, x, y)
    # 6 results: params', m', v', step', loss, acc
    assert f"f32[{p}]" in text
    assert "ENTRY" in text


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts`")
class TestBuiltManifest:
    def setup_method(self):
        self.manifest = json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_has_required_models(self):
        assert {"mnist", "cifar", "lm"} <= set(self.manifest["models"])

    def test_all_artifact_files_exist_and_are_hlo(self):
        for name, m in self.manifest["models"].items():
            for kind, art in m["artifacts"].items():
                path = ARTIFACTS / art["file"]
                assert path.exists(), f"{name}/{kind} missing"
                head = path.read_text()[:200]
                assert head.startswith("HloModule"), f"{name}/{kind} not HLO text"

    def test_agg_artifacts_cover_paper_node_counts(self):
        ks = {int(k) for k in self.manifest["agg"]["k"]}
        assert {2, 3, 5} <= ks  # the paper's node counts

    def test_param_counts_match_registry(self):
        for name in ("mnist", "cifar", "lm"):
            spec = get_model(name)
            assert self.manifest["models"][name]["param_count"] == T.param_count(spec)

    def test_lm14m_is_pythia_scale(self):
        if "lm14m" in self.manifest["models"]:
            p = self.manifest["models"]["lm14m"]["param_count"]
            assert 10_000_000 < p < 30_000_000


def test_aot_cli_smoke(tmp_path):
    """The aot CLI builds a single tiny artifact set end to end."""
    out = tmp_path / "arts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--models", "mnist", "--agg-k", "2", "--no-pallas"],
        cwd=pathlib.Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["use_pallas"] is False
    assert (out / "mnist_train.hlo.txt").exists()
