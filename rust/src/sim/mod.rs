//! Experiment driver: wire data + store + strategies + nodes together, run
//! a federated training experiment end-to-end, and evaluate the resulting
//! global model on the held-out test set — once per trial, with
//! mean ± 95% CI across trials (the paper's table cells).
//!
//! [`run_experiment`] is the single-trial entry point; [`run_trials`]
//! repeats it across seeds for one table cell; [`crate::sweep`] runs a
//! whole grid of cells in parallel.
//!
//! # Example
//!
//! ```no_run
//! use fedless::config::{ExperimentConfig, FederationMode};
//! use fedless::sim::{run_experiment, run_trials};
//!
//! let cfg = ExperimentConfig {
//!     model: "mnist".into(),
//!     n_nodes: 3,
//!     mode: FederationMode::Async,
//!     skew: 0.9,
//!     ..Default::default()
//! };
//! // one trial...
//! let result = run_experiment(&cfg).unwrap();
//! println!("accuracy = {:.3}", result.final_accuracy);
//! println!("{}", result.render_timelines(72));
//! // ...or a paper-style cell: mean ± 95% CI over three seeds
//! let cell = run_trials(&cfg, 3).unwrap();
//! println!("accuracy = {}", cell.accuracy.fmt_paper());
//! ```

mod experiment;
mod trial;

pub use experiment::{run_experiment, ExperimentResult};
pub use trial::{run_trials, TrialSet};
