//! The multi-trial scheduler: run an expanded sweep on a bounded pool of
//! worker threads.
//!
//! Work distribution is work-stealing in the self-scheduling sense: all
//! trials sit in one shared queue (an atomic cursor over the expanded
//! trial list) and every idle worker steals the next undone trial, so a
//! worker that drew short trials naturally takes more of them and no
//! static partition can leave a worker idle while trials remain. Trials
//! are fully independent — each owns its seed, its data shards and (via
//! [`super::spec::SweepSpec::expand`]'s namespacing) its store — so no
//! cross-trial synchronization exists beyond the queue cursor.
//!
//! The pool is bounded because each trial internally spawns `n_nodes` OS
//! threads, each with its own PJRT engine: `jobs` caps *trials* in
//! flight, so peak thread count is `jobs × max(n_nodes)`.
//!
//! Time: the sweep's own wall-clock (progress lines, `SweepReport`
//! header) is real time — it measures the scheduler. Each *trial's*
//! `wall_clock_s` is measured on that trial's own clock
//! ([`crate::sim::run_experiment`] builds one per trial from the base
//! config's `clock` key), so a `"clock": "virtual"` spec sweeps
//! straggler/latency grids at CPU speed while the per-cell wall-clock
//! columns report deterministic simulated seconds.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::sim::{run_experiment, ExperimentResult};

use super::report::{SweepReport, TrialMetrics, TrialOutcome};
use super::spec::SweepSpec;

/// Scheduler width when the spec leaves `jobs` at 0: the machine's
/// available parallelism, capped at 4 because every trial multiplies into
/// `n_nodes` node threads of its own.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(4)
        .max(1)
}

/// Run every trial of the sweep through [`crate::sim::run_experiment`]
/// and aggregate the results. Progress lines go to stderr as trials
/// finish; a failed trial is recorded in the report, not fatal to the
/// sweep.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    run_sweep_with(spec, run_experiment)
}

/// [`run_sweep`] with a custom trial runner — the seam that lets the
/// scheduler be tested (and reused) without artifacts or a PJRT runtime.
pub fn run_sweep_with<F>(spec: &SweepSpec, runner: F) -> Result<SweepReport>
where
    F: Fn(&ExperimentConfig) -> Result<ExperimentResult> + Sync,
{
    let trials = spec.expand()?;
    anyhow::ensure!(!trials.is_empty(), "sweep expands to zero trials");
    let n_workers = match spec.jobs {
        0 => default_jobs(),
        j => j,
    }
    .min(trials.len())
    .max(1);

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<TrialOutcome>>> =
        Mutex::new((0..trials.len()).map(|_| None).collect());
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                // Steal the next undone trial from the shared queue.
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= trials.len() {
                    break;
                }
                let trial = &trials[i];
                let run_name = trial.cfg.run_name();
                let t_trial = Instant::now();
                // A panicking trial must not sink the sweep (or the
                // worker): contain it like an Err. Node-thread panics are
                // already caught by NodeHandle::wait; this catches
                // driver-side panics (e.g. a degenerate data split).
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    runner(&trial.cfg)
                }));
                let result = match caught {
                    Ok(Ok(res)) => {
                        let traffic = res.total_traffic();
                        Ok(TrialMetrics {
                            accuracy: res.final_accuracy,
                            loss: res.final_loss,
                            wall_clock_s: res.wall_clock_s,
                            mb_pushed: traffic.mb_pushed(),
                            mb_pulled: traffic.mb_pulled(),
                            all_completed: res.all_completed,
                            mean_divergence: res
                                .divergence
                                .as_ref()
                                .and_then(|d| d.mean_l2()),
                            faults: res.fault_totals(),
                        })
                    }
                    Ok(Err(e)) => Err(format!("{e:#}")),
                    Err(panic) => Err(format!("trial panicked: {}", panic_msg(&panic))),
                };
                let finished = done.fetch_add(1, Ordering::SeqCst) + 1;
                match &result {
                    Ok(m) => eprintln!(
                        "[sweep {finished}/{}] {run_name}: acc={:.4} ({:.1}s)",
                        trials.len(),
                        m.accuracy,
                        t_trial.elapsed().as_secs_f64()
                    ),
                    Err(e) => eprintln!(
                        "[sweep {finished}/{}] {run_name}: FAILED: {e}",
                        trials.len()
                    ),
                }
                slots.lock().unwrap()[i] = Some(TrialOutcome {
                    trial_index: trial.trial_index,
                    cell_index: trial.cell_index,
                    run_name,
                    result,
                });
            });
        }
    });

    let outcomes: Vec<TrialOutcome> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every queued trial produces an outcome"))
        .collect();
    Ok(SweepReport::build(spec, &outcomes, n_workers, t0.elapsed().as_secs_f64()))
}

fn panic_msg(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    use super::*;
    use crate::config::FederationMode;

    fn fake_result(acc: f64) -> ExperimentResult {
        ExperimentResult {
            final_accuracy: acc,
            final_loss: 1.0 - acc,
            wall_clock_s: 0.01,
            reports: vec![],
            global_hash: 0,
            store_pushes: 0,
            mean_idle_fraction: 0.0,
            all_completed: true,
            divergence: None,
            trace_dir: None,
        }
    }

    fn grid_spec(jobs: usize) -> SweepSpec {
        let mut spec = SweepSpec::parse_json(
            r#"{"modes": ["sync", "async"], "skews": [0.0, 0.9], "seeds": [1, 2]}"#,
        )
        .unwrap();
        spec.jobs = jobs;
        spec
    }

    #[test]
    fn runs_every_trial_exactly_once() {
        let calls = AtomicUsize::new(0);
        let report = run_sweep_with(&grid_spec(3), |cfg| {
            calls.fetch_add(1, Ordering::SeqCst);
            Ok(fake_result(cfg.skew)) // echo the cell's skew as accuracy
        })
        .unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 8);
        assert_eq!(report.n_trials, 8);
        assert_eq!(report.n_failures, 0);
        assert_eq!(report.cells.len(), 4);
        // aggregation is per-cell: the skew-0.9 cells must average 0.9
        for c in &report.cells {
            let a = c.cell.skew;
            assert!((c.accuracy.unwrap().mean - a).abs() < 1e-12);
            assert_eq!(c.n_trials, 2);
        }
    }

    #[test]
    fn pool_is_bounded_by_jobs() {
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let report = run_sweep_with(&grid_spec(2), |_| {
            let cur = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(cur, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(15));
            in_flight.fetch_sub(1, Ordering::SeqCst);
            Ok(fake_result(0.5))
        })
        .unwrap();
        assert_eq!(report.n_workers, 2);
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "at most `jobs` trials in flight, saw {}",
            peak.load(Ordering::SeqCst)
        );
    }

    #[test]
    fn workers_capped_by_trial_count() {
        let spec = {
            let mut s = SweepSpec::parse_json(r#"{"seeds": [1]}"#).unwrap();
            s.jobs = 16;
            s
        };
        let report = run_sweep_with(&spec, |_| Ok(fake_result(0.5))).unwrap();
        assert_eq!(report.n_workers, 1);
    }

    #[test]
    fn a_failing_trial_does_not_sink_the_sweep() {
        let report = run_sweep_with(&grid_spec(4), |cfg| {
            if cfg.mode == FederationMode::Sync {
                anyhow::bail!("injected failure")
            }
            Ok(fake_result(0.7))
        })
        .unwrap();
        assert_eq!(report.n_failures, 4);
        for c in &report.cells {
            match c.cell.mode {
                FederationMode::Sync => {
                    assert_eq!(c.failures, 2);
                    assert!(c.accuracy.is_none());
                    assert!(c.first_error.as_deref().unwrap().contains("injected"));
                }
                _ => {
                    assert_eq!(c.failures, 0);
                    assert!((c.accuracy.unwrap().mean - 0.7).abs() < 1e-12);
                }
            }
        }
        let md = report.to_markdown();
        assert!(md.contains("FAILED"), "{md}");
    }

    #[test]
    fn a_panicking_trial_is_contained() {
        let report = run_sweep_with(&grid_spec(2), |cfg| {
            if cfg.skew > 0.5 {
                panic!("degenerate split");
            }
            Ok(fake_result(0.6))
        })
        .unwrap();
        assert_eq!(report.n_failures, 4);
        for c in &report.cells {
            if c.cell.skew > 0.5 {
                assert!(c.first_error.as_deref().unwrap().contains("degenerate split"));
            } else {
                assert!((c.accuracy.unwrap().mean - 0.6).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn trials_run_under_their_resolved_configs() {
        // The runner must see each cell's resolved (mode, skew, seed).
        let seen = Mutex::new(Vec::new());
        run_sweep_with(&grid_spec(1), |cfg| {
            seen.lock().unwrap().push((cfg.mode.name(), cfg.skew, cfg.seed));
            Ok(fake_result(0.5))
        })
        .unwrap();
        let mut seen = seen.into_inner().unwrap();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen.len(), 8);
        seen.dedup();
        assert_eq!(seen.len(), 8, "every trial has a distinct config");
    }
}
