//! [`SweepReport`] — aggregate sweep results into a paper-style table.
//!
//! Each grid cell's trials (one per seed) are summarized as mean ± std via
//! [`crate::metrics::stats::Summary`]; the report renders as a Markdown
//! table (the format of this repo's `fedbench` tables and the paper's §4
//! tables) and as CSV for downstream plotting.

use std::fmt::Write as _;

use crate::metrics::stats::Summary;

use super::spec::{CellKey, SweepSpec};

/// The scalar results the report keeps per successful trial.
#[derive(Clone, Copy, Debug)]
pub struct TrialMetrics {
    /// Held-out accuracy of the aggregated global model.
    pub accuracy: f64,
    /// Held-out mean loss of the global model.
    pub loss: f64,
    /// Trial wall-clock seconds.
    pub wall_clock_s: f64,
    /// Encoded megabytes pushed across all nodes (the
    /// [`crate::metrics::TrafficMeter`] totals).
    pub mb_pushed: f64,
    /// Encoded megabytes pulled across all nodes.
    pub mb_pulled: f64,
    /// Whether every node ran all its epochs.
    pub all_completed: bool,
    /// Mean per-round L2 divergence of client updates from the round
    /// aggregate ([`crate::trace::DivergenceReport::mean_l2`]); `None`
    /// when the trial ran untraced (`divergence` spec key off).
    pub mean_divergence: Option<f64>,
    /// Fault-tolerance-layer totals of the trial (injected faults,
    /// retries, give-ups, degraded rounds, restarts). All zero on a
    /// clean run; the chaos columns render only when some cell saw
    /// nonzero totals, so clean sweep tables stay byte-identical.
    pub faults: crate::trace::FaultTotals,
}

/// Outcome of one scheduled trial (success metrics or the error text).
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    /// Index into the expanded trial list.
    pub trial_index: usize,
    /// Index into [`SweepSpec::cells`].
    pub cell_index: usize,
    /// The trial's `ExperimentConfig::run_name` (for logs).
    pub run_name: String,
    /// Metrics on success, the rendered error on failure.
    pub result: Result<TrialMetrics, String>,
}

/// Per-cell aggregate over that cell's seeds.
#[derive(Clone, Debug)]
pub struct CellSummary {
    /// Which grid cell this row describes.
    pub cell: CellKey,
    /// Trials attempted in this cell.
    pub n_trials: usize,
    /// Trials that returned an error.
    pub failures: usize,
    /// Accuracy summary over successful trials (`None` if all failed).
    pub accuracy: Option<Summary>,
    /// Mean accuracy of this cell's *clean* run: for an honest cell
    /// (`adversary = None`) its own mean, for an attacked cell the mean
    /// of its clean sibling — the cell identical in every axis except
    /// `adversary` — when that sibling is in the grid. The paired
    /// `acc clean` / `acc attacked` report columns read attack damage
    /// off one row.
    pub acc_clean: Option<f64>,
    /// Mean accuracy under attack: set only for attacked cells.
    pub acc_attacked: Option<f64>,
    /// Loss summary over successful trials.
    pub loss: Option<Summary>,
    /// Wall-clock summary over successful trials.
    pub wall_clock: Option<Summary>,
    /// Pushed-megabytes summary over successful trials.
    pub mb_pushed: Option<Summary>,
    /// Pulled-megabytes summary over successful trials.
    pub mb_pulled: Option<Summary>,
    /// Mean-divergence summary over successful *traced* trials (`None`
    /// when the cell ran untraced — the column renders only when some
    /// cell has data, so untraced sweep tables are byte-identical to
    /// before the column existed).
    pub divergence: Option<Summary>,
    /// Injected-store-fault summary over successful trials (`None` if
    /// all failed). The Markdown chaos columns render only when some
    /// cell's fault-layer mean is nonzero.
    pub injected: Option<Summary>,
    /// Retried-store-op summary over successful trials.
    pub retries: Option<Summary>,
    /// Retry-give-up summary over successful trials.
    pub give_ups: Option<Summary>,
    /// Quorum-degraded sync-round summary over successful trials.
    pub degraded: Option<Summary>,
    /// Crash–restart recovery summary over successful trials.
    pub restarts: Option<Summary>,
    /// First error message, when any trial failed.
    pub first_error: Option<String>,
}

/// Aggregated results of one sweep run.
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// Model of the sweep's base config.
    pub model: String,
    /// One summary per grid cell, in [`SweepSpec::cells`] order.
    pub cells: Vec<CellSummary>,
    /// Total trials scheduled.
    pub n_trials: usize,
    /// Total trials that failed.
    pub n_failures: usize,
    /// Worker threads the scheduler used.
    pub n_workers: usize,
    /// Whole-sweep wall-clock seconds.
    pub wall_clock_s: f64,
}

impl SweepReport {
    /// Aggregate raw trial outcomes into per-cell summaries.
    pub(crate) fn build(
        spec: &SweepSpec,
        outcomes: &[TrialOutcome],
        n_workers: usize,
        wall_clock_s: f64,
    ) -> SweepReport {
        let keys = spec.cells();
        let mut cells: Vec<CellSummary> = keys
            .into_iter()
            .map(|cell| CellSummary {
                cell,
                n_trials: 0,
                failures: 0,
                accuracy: None,
                acc_clean: None,
                acc_attacked: None,
                loss: None,
                wall_clock: None,
                mb_pushed: None,
                mb_pulled: None,
                divergence: None,
                injected: None,
                retries: None,
                give_ups: None,
                degraded: None,
                restarts: None,
                first_error: None,
            })
            .collect();

        let mut accs: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        let mut losses: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        let mut walls: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        let mut pushed: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        let mut pulled: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        let mut divs: Vec<Vec<f64>> = vec![Vec::new(); cells.len()];
        // five per-trial fault-layer counters, in FaultTotals field order
        let mut chaos: Vec<[Vec<f64>; 5]> = vec![Default::default(); cells.len()];
        let mut n_failures = 0;
        for o in outcomes {
            let c = &mut cells[o.cell_index];
            c.n_trials += 1;
            match &o.result {
                Ok(m) => {
                    accs[o.cell_index].push(m.accuracy);
                    losses[o.cell_index].push(m.loss);
                    walls[o.cell_index].push(m.wall_clock_s);
                    pushed[o.cell_index].push(m.mb_pushed);
                    pulled[o.cell_index].push(m.mb_pulled);
                    if let Some(d) = m.mean_divergence {
                        divs[o.cell_index].push(d);
                    }
                    let f = &m.faults;
                    for (k, v) in [
                        f.injected_faults,
                        f.store_retries,
                        f.store_give_ups,
                        f.degraded_rounds,
                        f.restarts,
                    ]
                    .into_iter()
                    .enumerate()
                    {
                        chaos[o.cell_index][k].push(v as f64);
                    }
                }
                Err(e) => {
                    c.failures += 1;
                    n_failures += 1;
                    if c.first_error.is_none() {
                        c.first_error = Some(e.clone());
                    }
                }
            }
        }
        for (i, c) in cells.iter_mut().enumerate() {
            if !accs[i].is_empty() {
                c.accuracy = Some(Summary::of(&accs[i]));
                c.loss = Some(Summary::of(&losses[i]));
                c.wall_clock = Some(Summary::of(&walls[i]));
                c.mb_pushed = Some(Summary::of(&pushed[i]));
                c.mb_pulled = Some(Summary::of(&pulled[i]));
                if !divs[i].is_empty() {
                    c.divergence = Some(Summary::of(&divs[i]));
                }
                c.injected = Some(Summary::of(&chaos[i][0]));
                c.retries = Some(Summary::of(&chaos[i][1]));
                c.give_ups = Some(Summary::of(&chaos[i][2]));
                c.degraded = Some(Summary::of(&chaos[i][3]));
                c.restarts = Some(Summary::of(&chaos[i][4]));
            }
        }

        // Pair every attacked cell with its clean sibling (identical key,
        // `adversary = None`) so attack damage reads off a single row.
        let clean_means: Vec<Option<f64>> = cells
            .iter()
            .map(|c| {
                if c.cell.adversary.is_none() {
                    return c.accuracy.as_ref().map(|a| a.mean);
                }
                let sibling = CellKey { adversary: None, ..c.cell.clone() };
                cells
                    .iter()
                    .find(|other| other.cell == sibling)
                    .and_then(|other| other.accuracy.as_ref().map(|a| a.mean))
            })
            .collect();
        for (c, clean) in cells.iter_mut().zip(clean_means) {
            c.acc_clean = clean;
            if c.cell.adversary.is_some() {
                c.acc_attacked = c.accuracy.as_ref().map(|a| a.mean);
            }
        }

        SweepReport {
            model: spec.base.model.clone(),
            cells,
            n_trials: outcomes.len(),
            n_failures,
            n_workers,
            wall_clock_s,
        }
    }

    /// Paper-style Markdown table, one row per grid cell.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "## Sweep: {} — {} trial(s) over {} cell(s), {} worker(s), {:.1}s{}\n",
            self.model,
            self.n_trials,
            self.cells.len(),
            self.n_workers,
            self.wall_clock_s,
            if self.n_failures > 0 {
                format!(" — {} FAILED", self.n_failures)
            } else {
                String::new()
            }
        );
        // The divergence column renders only when some cell has data, so
        // untraced sweep tables stay byte-identical to the pre-column
        // format (the timing/determinism/robust goldens pin it).
        let has_div = self.cells.iter().any(|c| c.divergence.is_some());
        // chaos columns likewise render only when some cell actually saw
        // fault-layer activity, so clean sweeps keep the legacy shape
        let nonzero = |s: &Option<Summary>| s.as_ref().is_some_and(|x| x.mean > 0.0);
        let has_chaos = self.cells.iter().any(|c| {
            c.cell.fault > 0.0
                || nonzero(&c.injected)
                || nonzero(&c.retries)
                || nonzero(&c.give_ups)
                || nonzero(&c.degraded)
                || nonzero(&c.restarts)
        });
        out.push_str(
            "| mode | strategy | skew | nodes | compress | threads | part | adversary | trials | accuracy (mean ± std) | acc clean | acc attacked | loss (mean ± std) | wall-clock s | MB pushed | MB pulled |",
        );
        if has_div {
            out.push_str(" mean div L2 |");
        }
        if has_chaos {
            out.push_str(" fault | faults | retries | give-ups | degraded | restarts |");
        }
        out.push('\n');
        out.push_str(
            "|------|----------|------|-------|----------|---------|------|-----------|--------|-----------------------|-----------|--------------|-------------------|--------------|-----------|-----------|",
        );
        if has_div {
            out.push_str("-------------|");
        }
        if has_chaos {
            out.push_str("-------|--------|---------|----------|----------|----------|");
        }
        out.push('\n');
        for c in &self.cells {
            let trials = if c.failures > 0 {
                format!("{}/{}", c.n_trials - c.failures, c.n_trials)
            } else {
                format!("{}", c.n_trials)
            };
            let mb = |s: &Option<Summary>| {
                s.as_ref().map(|x| format!("{:.2}", x.mean)).unwrap_or_else(|| "-".into())
            };
            let acc3 = |v: &Option<f64>| {
                v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into())
            };
            let (acc, loss, wall) = match (&c.accuracy, &c.loss, &c.wall_clock) {
                (Some(a), Some(l), Some(w)) => {
                    (a.fmt_mean_std(), l.fmt_mean_std(), w.fmt_mean_std())
                }
                _ => {
                    let e = truncate(c.first_error.as_deref().unwrap_or("no trials"), 48);
                    (format!("ERR({e})"), "-".into(), "-".into())
                }
            };
            let _ = write!(
                out,
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |",
                c.cell.mode.label(),
                c.cell.strategy.label(),
                c.cell.skew,
                c.cell.n_nodes,
                c.cell.compress.label(),
                crate::config::threads_label(c.cell.threads),
                c.cell.participation,
                c.cell.adversary.map(|a| a.label()).unwrap_or_else(|| "none".into()),
                trials,
                acc,
                acc3(&c.acc_clean),
                acc3(&c.acc_attacked),
                loss,
                wall,
                mb(&c.mb_pushed),
                mb(&c.mb_pulled)
            );
            if has_div {
                let div = c
                    .divergence
                    .as_ref()
                    .map(|s| format!("{:.4}", s.mean))
                    .unwrap_or_else(|| "-".into());
                let _ = write!(out, " {div} |");
            }
            if has_chaos {
                let mean1 = |s: &Option<Summary>| {
                    s.as_ref().map(|x| format!("{:.1}", x.mean)).unwrap_or_else(|| "-".into())
                };
                let _ = write!(
                    out,
                    " {} | {} | {} | {} | {} | {} |",
                    c.cell.fault,
                    mean1(&c.injected),
                    mean1(&c.retries),
                    mean1(&c.give_ups),
                    mean1(&c.degraded),
                    mean1(&c.restarts),
                );
            }
            out.push('\n');
        }
        out
    }

    /// CSV with one row per grid cell (header included).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "model,mode,strategy,skew,n_nodes,compress,threads,participation,fault,adversary,\
             trials,failures,\
             acc_mean,acc_std,acc_clean,acc_attacked,loss_mean,loss_std,wall_mean,wall_std,\
             mb_pushed_mean,mb_pulled_mean,divergence_mean,\
             faults_mean,retries_mean,give_ups_mean,degraded_mean,restarts_mean\n",
        );
        let num = |s: &Option<Summary>, f: fn(&Summary) -> f64| -> String {
            s.as_ref().map(|x| format!("{}", f(x))).unwrap_or_default()
        };
        let opt = |v: &Option<f64>| -> String {
            v.map(|x| format!("{x}")).unwrap_or_default()
        };
        for c in &self.cells {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                self.model,
                c.cell.mode.label(),
                c.cell.strategy.label(),
                c.cell.skew,
                c.cell.n_nodes,
                c.cell.compress.label(),
                crate::config::threads_label(c.cell.threads),
                c.cell.participation,
                c.cell.fault,
                c.cell.adversary.map(|a| a.label()).unwrap_or_else(|| "none".into()),
                c.n_trials,
                c.failures,
                num(&c.accuracy, |s| s.mean),
                num(&c.accuracy, |s| s.std),
                opt(&c.acc_clean),
                opt(&c.acc_attacked),
                num(&c.loss, |s| s.mean),
                num(&c.loss, |s| s.std),
                num(&c.wall_clock, |s| s.mean),
                num(&c.wall_clock, |s| s.std),
                num(&c.mb_pushed, |s| s.mean),
                num(&c.mb_pulled, |s| s.mean),
                num(&c.divergence, |s| s.mean),
                num(&c.injected, |s| s.mean),
                num(&c.retries, |s| s.mean),
                num(&c.give_ups, |s| s.mean),
                num(&c.degraded, |s| s.mean),
                num(&c.restarts, |s| s.mean),
            );
        }
        out
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.len() <= max {
        s.to_string()
    } else {
        let cut = s
            .char_indices()
            .take_while(|(i, _)| *i < max)
            .last()
            .map(|(i, _)| i)
            .unwrap_or(0);
        format!("{}...", &s[..cut])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::SweepSpec;

    fn outcome(cell: usize, i: usize, acc: f64) -> TrialOutcome {
        TrialOutcome {
            trial_index: i,
            cell_index: cell,
            run_name: format!("t{i}"),
            result: Ok(TrialMetrics {
                accuracy: acc,
                loss: 1.0 - acc,
                wall_clock_s: 2.0,
                mb_pushed: 1.5,
                mb_pulled: 3.0,
                all_completed: true,
                mean_divergence: None,
                faults: crate::trace::FaultTotals::default(),
            }),
        }
    }

    fn outcome_with_divergence(cell: usize, i: usize, acc: f64, div: f64) -> TrialOutcome {
        let mut o = outcome(cell, i, acc);
        if let Ok(m) = &mut o.result {
            m.mean_divergence = Some(div);
        }
        o
    }

    fn failure(cell: usize, i: usize, msg: &str) -> TrialOutcome {
        TrialOutcome {
            trial_index: i,
            cell_index: cell,
            run_name: format!("t{i}"),
            result: Err(msg.to_string()),
        }
    }

    fn two_cell_spec() -> SweepSpec {
        SweepSpec::parse_json(r#"{"modes": ["sync", "async"], "seeds": [1, 2]}"#).unwrap()
    }

    #[test]
    fn aggregates_mean_and_std_per_cell() {
        let spec = two_cell_spec();
        let outcomes = vec![
            outcome(0, 0, 0.9),
            outcome(0, 1, 0.7),
            outcome(1, 2, 0.5),
            outcome(1, 3, 0.5),
        ];
        let r = SweepReport::build(&spec, &outcomes, 2, 4.0);
        assert_eq!(r.cells.len(), 2);
        assert_eq!(r.n_trials, 4);
        assert_eq!(r.n_failures, 0);
        let a0 = r.cells[0].accuracy.unwrap();
        assert!((a0.mean - 0.8).abs() < 1e-12);
        assert!(a0.std > 0.1);
        let a1 = r.cells[1].accuracy.unwrap();
        assert_eq!(a1.std, 0.0);
    }

    #[test]
    fn markdown_has_one_row_per_cell() {
        let spec = two_cell_spec();
        let outcomes =
            vec![outcome(0, 0, 0.9), outcome(0, 1, 0.7), outcome(1, 2, 0.5), outcome(1, 3, 0.5)];
        let md = SweepReport::build(&spec, &outcomes, 2, 4.0).to_markdown();
        assert_eq!(md.lines().filter(|l| l.starts_with("| sync")).count(), 1);
        assert_eq!(md.lines().filter(|l| l.starts_with("| async")).count(), 1);
        assert!(md.contains("0.800 ± 0.141"), "{md}");
        assert!(md.contains("4 trial(s) over 2 cell(s)"), "{md}");
    }

    #[test]
    fn failed_cells_render_err_and_partial_counts() {
        let spec = two_cell_spec();
        let outcomes = vec![
            failure(0, 0, "boom"),
            failure(0, 1, "boom"),
            outcome(1, 2, 0.5),
            failure(1, 3, "later"),
        ];
        let r = SweepReport::build(&spec, &outcomes, 1, 4.0);
        assert_eq!(r.n_failures, 3);
        assert!(r.cells[0].accuracy.is_none());
        assert_eq!(r.cells[0].first_error.as_deref(), Some("boom"));
        let md = r.to_markdown();
        assert!(md.contains("ERR(boom)"), "{md}");
        assert!(md.contains("| 1/2 |"), "{md}");
        assert!(md.contains("3 FAILED"), "{md}");
    }

    #[test]
    fn csv_shape() {
        let spec = two_cell_spec();
        let outcomes = vec![outcome(0, 0, 0.9), outcome(1, 1, 0.5)];
        let csv = SweepReport::build(&spec, &outcomes, 1, 1.0).to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 cells
        assert!(lines[0].starts_with("model,mode,strategy"));
        let cols = lines[1].split(',').count();
        assert_eq!(cols, lines[0].split(',').count());
    }

    #[test]
    fn adversary_cells_pair_with_their_clean_sibling() {
        // adversary axis is innermost: cell 0 = clean, 1 = byz1 (fedavg),
        // then 2 = clean, 3 = byz1 (median)
        let spec = SweepSpec::parse_json(
            r#"{"modes": "sync", "strategies": ["fedavg", "median"],
                "adversary": ["none", "byzantine:1"], "n_nodes": 4}"#,
        )
        .unwrap();
        let outcomes = vec![
            outcome(0, 0, 0.9),
            outcome(1, 1, 0.2),
            outcome(2, 2, 0.88),
            outcome(3, 3, 0.87),
        ];
        let r = SweepReport::build(&spec, &outcomes, 1, 1.0);
        // clean cells: own mean in acc_clean, no attacked value
        assert_eq!(r.cells[0].acc_clean, Some(0.9));
        assert_eq!(r.cells[0].acc_attacked, None);
        // attacked cells: sibling's clean mean paired with own mean
        assert_eq!(r.cells[1].acc_clean, Some(0.9));
        assert_eq!(r.cells[1].acc_attacked, Some(0.2));
        assert_eq!(r.cells[3].acc_clean, Some(0.88));
        assert_eq!(r.cells[3].acc_attacked, Some(0.87));
        let md = r.to_markdown();
        assert!(md.contains("| acc clean | acc attacked |"), "{md}");
        assert!(md.contains("| part | adversary |"), "{md}");
        assert!(md.contains("| 1 | byz1 |"), "{md}");
        assert!(md.contains("| 0.900 | 0.200 |"), "{md}");
        assert!(md.contains("| 0.900 | - |"), "{md}");
        let csv = r.to_csv();
        assert!(csv.contains("adversary,trials"), "{csv}");
        assert!(csv.contains(",byz1,"), "{csv}");
        assert!(csv.lines().nth(2).unwrap().contains(",0.9,0.2,"), "{csv}");
    }

    #[test]
    fn attacked_cell_without_clean_sibling_renders_dash() {
        let spec = SweepSpec::parse_json(
            r#"{"modes": "sync", "adversary": "signflip:1", "n_nodes": 4}"#,
        )
        .unwrap();
        let r = SweepReport::build(&spec, &[outcome(0, 0, 0.4)], 1, 1.0);
        assert_eq!(r.cells[0].acc_clean, None, "no clean sibling in the grid");
        assert_eq!(r.cells[0].acc_attacked, Some(0.4));
        assert!(r.to_markdown().contains("| - | 0.400 |"));
    }

    #[test]
    fn divergence_column_renders_only_when_some_cell_has_data() {
        let spec = two_cell_spec();
        // untraced: no divergence column anywhere (goldens pin this shape)
        let md = SweepReport::build(
            &spec,
            &[outcome(0, 0, 0.9), outcome(1, 1, 0.5)],
            1,
            1.0,
        )
        .to_markdown();
        assert!(!md.contains("mean div L2"), "{md}");
        assert!(md.lines().nth(2).unwrap().ends_with("| MB pulled |"), "{md}");
        // traced: column appears, untraced cells render '-'
        let r = SweepReport::build(
            &spec,
            &[
                outcome_with_divergence(0, 0, 0.9, 0.125),
                outcome_with_divergence(0, 1, 0.9, 0.375),
                outcome(1, 2, 0.5),
            ],
            1,
            1.0,
        );
        assert!((r.cells[0].divergence.unwrap().mean - 0.25).abs() < 1e-12);
        assert!(r.cells[1].divergence.is_none());
        let md = r.to_markdown();
        assert!(md.contains("| MB pushed | MB pulled | mean div L2 |"), "{md}");
        assert!(md.contains("| 0.2500 |"), "{md}");
        assert!(md.lines().last().unwrap().ends_with("| - |"), "{md}");
        let csv = r.to_csv();
        assert!(csv.contains("mb_pulled_mean,divergence_mean"), "{csv}");
        let cols = csv.lines().nth(1).unwrap().split(',').count();
        assert_eq!(cols, csv.lines().next().unwrap().split(',').count());
        assert!(csv.lines().nth(1).unwrap().contains(",0.25,"), "{csv}");
    }

    #[test]
    fn chaos_columns_render_only_when_a_cell_saw_faults() {
        let spec = two_cell_spec();
        // clean outcomes: no chaos columns anywhere (goldens pin this)
        let md = SweepReport::build(
            &spec,
            &[outcome(0, 0, 0.9), outcome(1, 1, 0.5)],
            1,
            1.0,
        )
        .to_markdown();
        assert!(!md.contains("| faults |"), "{md}");
        assert!(!md.contains("| restarts |"), "{md}");
        // a trial with fault-layer activity turns the columns on
        let mut chaotic = outcome(0, 0, 0.9);
        if let Ok(m) = &mut chaotic.result {
            m.faults.injected_faults = 6;
            m.faults.store_retries = 6;
            m.faults.degraded_rounds = 1;
        }
        let r = SweepReport::build(&spec, &[chaotic, outcome(1, 1, 0.5)], 1, 1.0);
        assert_eq!(r.cells[0].injected.unwrap().mean, 6.0);
        assert_eq!(r.cells[0].degraded.unwrap().mean, 1.0);
        assert_eq!(r.cells[1].injected.unwrap().mean, 0.0);
        let md = r.to_markdown();
        assert!(
            md.contains("| fault | faults | retries | give-ups | degraded | restarts |"),
            "{md}"
        );
        assert!(md.contains("| 6.0 | 6.0 | 0.0 | 1.0 | 0.0 |"), "{md}");
        let csv = r.to_csv();
        assert!(csv.contains("faults_mean,retries_mean,give_ups_mean"), "{csv}");
        assert!(csv.lines().nth(1).unwrap().ends_with(",6,6,0,1,0"), "{csv}");
        // a nonzero fault axis alone also turns the columns on, so a
        // lucky fault cell with zero injections still shows its p
        let spec = SweepSpec::parse_json(r#"{"fault": 0.05}"#).unwrap();
        let md = SweepReport::build(&spec, &[outcome(0, 0, 0.9)], 1, 1.0).to_markdown();
        assert!(md.contains("| 0.05 | 0.0 |"), "{md}");
    }

    #[test]
    fn traffic_and_compress_columns_render() {
        let spec = SweepSpec::parse_json(
            r#"{"compress": ["none", "q8"], "seeds": [1, 2]}"#,
        )
        .unwrap();
        let outcomes = vec![
            outcome(0, 0, 0.9),
            outcome(0, 1, 0.9),
            outcome(1, 2, 0.9),
            outcome(1, 3, 0.9),
        ];
        let r = SweepReport::build(&spec, &outcomes, 1, 1.0);
        assert!((r.cells[0].mb_pushed.unwrap().mean - 1.5).abs() < 1e-12);
        assert!((r.cells[0].mb_pulled.unwrap().mean - 3.0).abs() < 1e-12);
        let md = r.to_markdown();
        assert!(md.contains("| MB pushed | MB pulled |"), "{md}");
        assert!(md.contains("| none |"), "{md}");
        assert!(md.contains("| q8 |"), "{md}");
        assert!(md.contains("| 1.50 | 3.00 |"), "{md}");
        let csv = r.to_csv();
        assert!(csv.contains("mb_pushed_mean,mb_pulled_mean"), "{csv}");
        assert!(csv.lines().nth(2).unwrap().contains(",q8,"), "{csv}");
    }
}
