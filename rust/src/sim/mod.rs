//! Experiment driver: wire data + store + strategies + nodes together, run
//! a federated training experiment end-to-end, and evaluate the resulting
//! global model on the held-out test set — once per trial, with
//! mean ± 95% CI across trials (the paper's table cells).

mod experiment;
mod trial;

pub use experiment::{run_experiment, ExperimentResult};
pub use trial::{run_trials, TrialSet};
