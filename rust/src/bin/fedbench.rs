//! `fedbench` — regenerates every table and figure of the paper's
//! evaluation (§4) on the synthetic substrate, at a configurable scale.
//!
//! ```text
//! fedbench table1 [--scale smoke|small|paper] [--trials N] [--out FILE]
//! fedbench table2|table3|table4|table5|table6|table7
//! fedbench fig1          straggler timelines + sync/async wall-clock
//! fedbench robustness    crash injection: async survives, sync stalls
//! fedbench all           every table at the chosen scale
//! fedbench run [--mode sync|async|local|gossip[:m]] [--model M]
//!              [--nodes N] [--skew S] [--strategy S] [--scale S] [--seed S]
//!              [--compress none|q8|topk:<f>|delta-q8] [--threads auto|N]
//!              [--robust median|trimmed-mean[:f]|krum[:f]|trust-weighted]
//!              [--adversary none|byzantine[:k]|scale[:f]|signflip[:k]|stale[:r]]
//!              [--scheduler threads|events] [--participation F]
//!              [--availability none|churn:<p>|diurnal:<period>|stragglers:<frac>:<mult>]
//!              [--fault P] [--outage <start_s>:<dur_s>[,...]] [--sync-quorum F]
//!              [--virtual-clock] [--trace|--no-trace] [--synthetic]
//!                        run one experiment at a preset scale (the
//!                        quickest way to try a protocol, e.g.
//!                        `fedbench run --mode gossip:2 --nodes 5`, a
//!                        codec: `fedbench run --compress q8`, or an
//!                        attack scenario: `fedbench run --nodes 4
//!                        --mode sync --robust krum:1 --adversary
//!                        byzantine:1`). Tracing is on by default:
//!                        the run exports `trace.jsonl`,
//!                        `trace_chrome.json` (Perfetto-loadable) and
//!                        `analysis.json` under `runs/<name>/`.
//!                        `--synthetic` runs the protocol layer on
//!                        synthetic weights (no datasets, no PJRT) —
//!                        the quickest way to produce a trace.
//! fedbench inspect <run-dir>
//!                        per-round divergence tables + per-node span
//!                        shares from a traced run's `analysis.json`
//! fedbench sweep SPEC.json [--jobs N] [--out FILE] [--csv FILE]
//!                        run a custom experiment grid in parallel
//! ```
//!
//! `--virtual-clock` (any experiment; also the `"clock": "virtual"`
//! sweep-spec key) runs on simulated time: straggler delays, injected
//! store latency, and barrier timeouts advance a discrete-event clock
//! instead of sleeping for real, so `fig1`-style timing experiments
//! finish in milliseconds while reporting faithful simulated wall-clock.
//!
//! Each cell reports `mean ± 95% CI` over repeated trials next to the
//! paper's value. Absolute numbers differ (synthetic data, scaled steps —
//! DESIGN.md §Substitutions); the comparisons that matter are the *shapes*:
//! sync ≈ async at low skew, degradation at high skew, FedAvg ≈ FedAvgM >
//! FedAdam, accuracy falling with node count, async < sync wall-clock under
//! stragglers.

use std::fmt::Write as _;
use std::time::Duration;

use fedless::compress::CodecKind;
use fedless::config::{ClockKind, CrashSpec, ExperimentConfig, FederationMode, Scale};
use fedless::sim::{run_experiment, run_trials};
use fedless::strategy::StrategyKind;
use fedless::sweep::{run_sweep, SweepSpec};

// ---------------------------------------------------------------------------
// scale presets

#[derive(Clone, Copy)]
struct Preset {
    epochs: usize,
    steps: usize,
    trials: usize,
    train_size: usize,
    test_size: usize,
}

fn preset(scale: Scale, model: &str) -> Preset {
    // Paper: MNIST 3 epochs x 1200 steps b32; CIFAR 20 x 1200 b128 (we use
    // b32); LM 3 epochs over 100k examples. Small/smoke shrink steps but
    // keep the *relative* structure (federation at every epoch end).
    match (scale, model) {
        (Scale::Smoke, "cifar") => Preset { epochs: 2, steps: 12, trials: 1, train_size: 1200, test_size: 320 },
        (Scale::Smoke, m) if m.starts_with("lm") => Preset { epochs: 2, steps: 20, trials: 1, train_size: 800, test_size: 160 },
        (Scale::Smoke, _) => Preset { epochs: 2, steps: 25, trials: 1, train_size: 2000, test_size: 320 },
        (Scale::Small, "cifar") => Preset { epochs: 4, steps: 60, trials: 2, train_size: 6000, test_size: 960 },
        (Scale::Small, m) if m.starts_with("lm") => Preset { epochs: 3, steps: 120, trials: 3, train_size: 4000, test_size: 400 },
        (Scale::Small, _) => Preset { epochs: 3, steps: 150, trials: 3, train_size: 8000, test_size: 1600 },
        (Scale::Paper, "cifar") => Preset { epochs: 20, steps: 1200, trials: 3, train_size: 50_000, test_size: 10_000 },
        (Scale::Paper, m) if m.starts_with("lm") => Preset { epochs: 3, steps: 780, trials: 3, train_size: 100_000, test_size: 1000 },
        (Scale::Paper, _) => Preset { epochs: 3, steps: 1200, trials: 3, train_size: 38_400, test_size: 10_000 },
    }
}

fn base_cfg(model: &str, scale: Scale) -> ExperimentConfig {
    let p = preset(scale, model);
    ExperimentConfig {
        model: model.into(),
        epochs: p.epochs,
        steps_per_epoch: p.steps,
        train_size: p.train_size,
        test_size: p.test_size,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// table runner helpers

struct Opts {
    scale: Scale,
    trials: Option<usize>,
    out: Option<String>,
    seed: u64,
    clock: ClockKind,
}

impl Opts {
    /// A base config for `model` at this run's scale and clock.
    fn cfg(&self, model: &str) -> ExperimentConfig {
        let mut cfg = base_cfg(model, self.scale);
        cfg.clock = self.clock;
        cfg
    }
}

struct TableOut {
    text: String,
}

impl TableOut {
    fn new(title: &str) -> Self {
        let mut t = TableOut { text: String::new() };
        let _ = writeln!(t.text, "\n## {title}\n");
        println!("\n## {title}\n");
        t
    }
    fn line(&mut self, s: &str) {
        println!("{s}");
        self.text.push_str(s);
        self.text.push('\n');
    }
}

fn cell(cfg: &ExperimentConfig, trials: usize) -> String {
    match run_trials(cfg, trials) {
        Ok(set) => set.accuracy.fmt_paper(),
        Err(e) => format!("ERR({e})"),
    }
}

fn trials_for(o: &Opts, model: &str) -> usize {
    o.trials.unwrap_or(preset(o.scale, model).trials)
}

// ---------------------------------------------------------------------------
// tables

/// Tables 1 (mnist) and 4 (cifar): sync vs async FedAvg across skew,
/// plus the centralized reference the captions quote.
fn table_sync_vs_async(model: &str, o: &Opts, paper: &[[&str; 3]; 2], centralized: &str) -> TableOut {
    let n = if model == "mnist" { 1 } else { 4 };
    let mut t = TableOut::new(&format!(
        "Table {n}: {model} sync vs async FedAvg across skew (2 nodes), scale={}",
        o.scale.name()
    ));
    let trials = trials_for(o, model);
    let skews = [0.0, 0.9, 1.0];

    // centralized reference
    let mut c = o.cfg(model);
    c.mode = FederationMode::Local;
    c.n_nodes = 1;
    c.seed = o.seed;
    let cen = cell(&c, trials);
    t.line(&format!("centralized reference: {cen}   (paper: {centralized})"));
    t.line("");
    t.line("| strategy | skew 0 | skew 0.9 | skew 1 |");
    t.line("|----------|--------|----------|--------|");
    for (row, mode) in [FederationMode::Sync, FederationMode::Async].iter().enumerate() {
        let mut cells = Vec::new();
        for (col, &skew) in skews.iter().enumerate() {
            let mut cfg = o.cfg(model);
            cfg.mode = *mode;
            cfg.n_nodes = 2;
            cfg.skew = skew;
            cfg.seed = o.seed;
            cells.push(format!("{} (paper {})", cell(&cfg, trials), paper[row][col]));
        }
        t.line(&format!("| {} | {} |", mode.name(), cells.join(" | ")));
    }
    t
}

/// Tables 2/3 (mnist) and 5/6 (cifar): strategies x node counts at a fixed
/// skew, sync and async variants.
fn table_strategies(
    model: &str,
    skew: f64,
    table_no: usize,
    o: &Opts,
    rows: &[(StrategyKind, FederationMode, [&str; 3])],
) -> TableOut {
    let mut t = TableOut::new(&format!(
        "Table {table_no}: {model} strategies x nodes, skew={skew}, scale={}",
        o.scale.name()
    ));
    let trials = trials_for(o, model);
    t.line("| strategy | 2 nodes | 3 nodes | 5 nodes |");
    t.line("|----------|---------|---------|---------|");
    for (kind, mode, paper) in rows {
        let mut cells = Vec::new();
        for (col, n_nodes) in [2usize, 3, 5].iter().enumerate() {
            let mut cfg = o.cfg(model);
            cfg.strategy = *kind;
            cfg.mode = *mode;
            cfg.n_nodes = *n_nodes;
            cfg.skew = skew;
            cfg.seed = o.seed;
            cells.push(format!("{} (paper {})", cell(&cfg, trials), paper[col]));
        }
        let label = match mode {
            FederationMode::Async => format!("{} (async)", kind.name()),
            _ => kind.name().to_string(),
        };
        t.line(&format!("| {label} | {} |", cells.join(" | ")));
    }
    t
}

/// Table 7: LM sync vs async FedAvg across node counts.
fn table7(o: &Opts) -> TableOut {
    let model = "lm";
    let mut t = TableOut::new(&format!(
        "Table 7: language model sync vs async FedAvg across nodes, scale={}",
        o.scale.name()
    ));
    let trials = trials_for(o, model);

    let mut c = o.cfg(model);
    c.mode = FederationMode::Local;
    c.n_nodes = 1;
    c.seed = o.seed;
    t.line(&format!("centralized reference: {}   (paper: 0.279)", cell(&c, trials)));
    t.line("");
    t.line("| strategy | 2 nodes | 3 nodes | 5 nodes |");
    t.line("|----------|---------|---------|---------|");
    let paper = [[".26 ± .002", ".237 ± .004", ".227 ± .008"],
                 [".251 ± .005", ".239 ± .006", ".221 ± .006"]];
    for (row, mode) in [FederationMode::Sync, FederationMode::Async].iter().enumerate() {
        let mut cells = Vec::new();
        for (col, n_nodes) in [2usize, 3, 5].iter().enumerate() {
            let mut cfg = o.cfg(model);
            cfg.mode = *mode;
            cfg.n_nodes = *n_nodes;
            cfg.seed = o.seed;
            cells.push(format!("{} (paper {})", cell(&cfg, trials), paper[row][col]));
        }
        let label = if *mode == FederationMode::Async { "FedAvg (async)" } else { "FedAvg" };
        t.line(&format!("| {label} | {} |", cells.join(" | ")));
    }
    t
}

/// Figure 1 (shape): straggler idle time under sync vs async + wall-clock.
fn fig1(o: &Opts) -> TableOut {
    let mut t = TableOut::new(&format!(
        "Figure 1: straggler idle time, sync vs async (scale={})",
        o.scale.name()
    ));
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let mut cfg = o.cfg("mnist");
        cfg.mode = mode;
        cfg.n_nodes = 3;
        cfg.seed = o.seed;
        // heterogeneous speeds: node 2 is much slower per step
        cfg.node_delays_ms = vec![0.0, 4.0, 16.0];
        match run_experiment(&cfg) {
            Ok(res) => {
                t.line(&format!(
                    "\n### {} — wall clock {:.2}s, mean idle {:.1}%",
                    mode.name(),
                    res.wall_clock_s,
                    100.0 * res.mean_idle_fraction
                ));
                for line in res.render_timelines(72).lines() {
                    t.line(line);
                }
            }
            Err(e) => t.line(&format!("{}: ERR {e}", mode.name())),
        }
    }
    t.line("\nAsync removes the '.' (wait) spans: fast nodes keep training while");
    t.line("the straggler finishes — the paper's Figure 1 phenomenon.");
    t
}

/// §4.2.1 robustness: a node crashes mid-training; async finishes, sync
/// stalls at the barrier.
fn robustness(o: &Opts) -> TableOut {
    let mut t = TableOut::new("Robustness: node crash at epoch 1 (paper §4.2.1)");
    for mode in [FederationMode::Sync, FederationMode::Async] {
        let mut cfg = o.cfg("mnist");
        cfg.mode = mode;
        cfg.n_nodes = 3;
        cfg.seed = o.seed;
        cfg.crash = Some(CrashSpec::at(1, 1));
        cfg.sync_timeout = Duration::from_secs(5);
        match run_experiment(&cfg) {
            Ok(res) => {
                let statuses: Vec<String> =
                    res.reports.iter().map(|r| format!("{:?}", r.status)).collect();
                t.line(&format!(
                    "{:5} -> completed={} acc={:.3} wall={:.1}s statuses={:?}",
                    mode.name(),
                    res.all_completed,
                    res.final_accuracy,
                    res.wall_clock_s,
                    statuses
                ));
            }
            Err(e) => t.line(&format!("{}: ERR {e}", mode.name())),
        }
    }
    t.line("\nExpected: async nodes 0/2 complete all epochs; sync nodes stall at");
    t.line("the round-1 barrier waiting for the crashed node (bounded by the");
    t.line("sync_timeout instead of hanging forever).");
    t
}

// ---------------------------------------------------------------------------

const T2_ROWS: &[(StrategyKind, FederationMode, [&str; 3])] = &[
    (StrategyKind::FedAvg, FederationMode::Sync, [".983 ± .002", ".983 ± .001", ".979 ± .001"]),
    (StrategyKind::FedAvgM, FederationMode::Sync, [".983 ± .001", ".983 ± .001", ".979 ± .001"]),
    (StrategyKind::FedAdam, FederationMode::Sync, [".976 ± .002", ".97 ± .007", ".962 ± .007"]),
    (StrategyKind::FedAvg, FederationMode::Async, [".976 ± .003", ".979 ± .002", ".97 ± .007"]),
    (StrategyKind::FedAvgM, FederationMode::Async, [".981 ± .002", ".979 ± .001", ".971 ± .003"]),
    (StrategyKind::FedAdam, FederationMode::Async, [".97 ± .005", ".928 ± .058", ".95 ± .012"]),
];

const T3_ROWS: &[(StrategyKind, FederationMode, [&str; 3])] = &[
    (StrategyKind::FedAvg, FederationMode::Sync, [".975 ± .003", ".965 ± .002", ".949 ± .002"]),
    (StrategyKind::FedAvgM, FederationMode::Sync, [".976 ± .002", ".965 ± .002", ".947 ± .001"]),
    (StrategyKind::FedAdam, FederationMode::Sync, [".967 ± .003", ".95 ± .005", ".926 ± .006"]),
    (StrategyKind::FedAvg, FederationMode::Async, [".971 ± .003", ".948 ± .005", ".928 ± .003"]),
    (StrategyKind::FedAvgM, FederationMode::Async, [".967 ± .005", ".953 ± .009", ".925 ± .013"]),
    (StrategyKind::FedAdam, FederationMode::Async, [".956 ± .014", ".91 ± .021", ".903 ± .015"]),
];

const T5_ROWS: &[(StrategyKind, FederationMode, [&str; 3])] = &[
    (StrategyKind::FedAvg, FederationMode::Sync, [".744 ± .01", ".717 ± .005", ".69 ± .002"]),
    (StrategyKind::FedAvgM, FederationMode::Sync, [".749 ± .002", ".715 ± .01", ".689 ± .004"]),
    (StrategyKind::FedAvg, FederationMode::Async, [".753 ± .018", ".728 ± .003", ".692 ± .003"]),
    (StrategyKind::FedAvgM, FederationMode::Async, [".733 ± .012", ".733 ± .006", ".689 ± .004"]),
];

const T6_ROWS: &[(StrategyKind, FederationMode, [&str; 3])] = &[
    (StrategyKind::FedAvg, FederationMode::Sync, [".552 ± .019", ".545 ± .021", ".43 ± .026"]),
    (StrategyKind::FedAvgM, FederationMode::Sync, [".566 ± .014", ".458 ± .006", ".441 ± .022"]),
    (StrategyKind::FedAvg, FederationMode::Async, [".615 ± .044", ".577 ± .024", ".418 ± .03"]),
    (StrategyKind::FedAvgM, FederationMode::Async, [".651 ± .011", ".564 ± .012", ".433 ± .028"]),
];

fn run_one(name: &str, o: &Opts) -> Option<TableOut> {
    let t1_paper = [[".987 ± .001", ".983 ± .002", ".894 ± .02"],
                    [".985 ± .001", ".976 ± .003", ".734 ± .114"]];
    let t4_paper = [[".804 ± .003", ".744 ± .01", ".477 ± .014"],
                    [".802 ± .004", ".753 ± .018", ".505 ± .048"]];
    match name {
        "table1" => Some(table_sync_vs_async("mnist", o, &t1_paper, "0.987")),
        "table2" => Some(table_strategies("mnist", 0.9, 2, o, T2_ROWS)),
        // Table 3 is the same grid at skew 0.99 (paper §4.2.2).
        "table3" => Some(table_strategies("mnist", 0.99, 3, o, T3_ROWS)),
        "table4" => Some(table_sync_vs_async("cifar", o, &t4_paper, "0.803")),
        "table5" => Some(table_strategies("cifar", 0.9, 5, o, T5_ROWS)),
        "table6" => Some(table_strategies("cifar", 0.99, 6, o, T6_ROWS)),
        "table7" => Some(table7(o)),
        "fig1" => Some(fig1(o)),
        "robustness" => Some(robustness(o)),
        _ => None,
    }
}

/// `fedbench run [--mode M] [--model M] [--nodes N] [--skew S]
/// [--strategy S] [--scale S] [--seed S] [--virtual-clock]` — one
/// experiment at a preset scale; the quickest way to exercise any
/// protocol end-to-end.
fn cmd_run(args: &[String]) -> Result<(), String> {
    let mut cfg = base_cfg("mnist", Scale::Small);
    // tracing is on by default for `fedbench run` (opt out: --no-trace)
    cfg.trace = true;
    let mut synthetic = false;
    let mut scale = Scale::Small;
    let mut model = String::from("mnist");
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].clone();
        i += 1;
        if flag == "--virtual-clock" {
            cfg.clock = ClockKind::Virtual;
            continue;
        }
        if flag == "--trace" {
            cfg.trace = true;
            continue;
        }
        if flag == "--no-trace" {
            cfg.trace = false;
            continue;
        }
        if flag == "--synthetic" {
            synthetic = true;
            continue;
        }
        let value = args
            .get(i)
            .ok_or_else(|| format!("{flag} needs a value"))?;
        match flag.as_str() {
            "--mode" => {
                cfg.mode = FederationMode::parse(value)
                    .ok_or_else(|| format!("bad --mode {value:?}"))?;
            }
            "--model" => model = value.clone(),
            "--nodes" => {
                cfg.n_nodes = value.parse().map_err(|_| format!("bad --nodes {value:?}"))?;
            }
            "--skew" => {
                cfg.skew = value.parse().map_err(|_| format!("bad --skew {value:?}"))?;
            }
            "--strategy" => {
                cfg.strategy = StrategyKind::parse(value)
                    .ok_or_else(|| format!("bad --strategy {value:?}"))?;
            }
            "--compress" => {
                cfg.compress = CodecKind::parse(value)
                    .ok_or_else(|| format!("bad --compress {value:?}"))?;
            }
            "--robust" => {
                let kind = StrategyKind::parse(value)
                    .filter(|k| k.is_robust())
                    .ok_or_else(|| {
                        format!(
                            "bad --robust {value:?} (median, trimmed-mean[:f], \
                             krum[:f], trust-weighted)"
                        )
                    })?;
                cfg.strategy = kind;
            }
            "--adversary" => {
                cfg.adversary = match value.as_str() {
                    "none" => None,
                    spec => Some(
                        fedless::store::AdversarySpec::parse(spec)
                            .ok_or_else(|| format!("bad --adversary {value:?}"))?,
                    ),
                };
            }
            "--threads" => {
                cfg.threads = fedless::config::parse_threads(value)
                    .ok_or_else(|| format!("bad --threads {value:?} (auto or >= 1)"))?;
            }
            "--scheduler" => {
                cfg.scheduler = fedless::config::SchedulerKind::parse(value)
                    .ok_or_else(|| format!("bad --scheduler {value:?} (threads or events)"))?;
            }
            "--participation" => {
                cfg.participation = value
                    .parse()
                    .map_err(|_| format!("bad --participation {value:?} (fraction in (0, 1])"))?;
            }
            "--availability" => {
                cfg.availability =
                    fedless::config::AvailabilitySpec::parse(value).ok_or_else(|| {
                        format!(
                            "bad --availability {value:?} (none, churn:<p>, \
                             diurnal:<period>, stragglers:<frac>:<mult>)"
                        )
                    })?;
            }
            "--fault" => {
                cfg.fault.p_fail = value
                    .parse()
                    .map_err(|_| format!("bad --fault {value:?} (probability in [0, 1])"))?;
            }
            "--outage" => {
                cfg.fault.outages = value
                    .split(',')
                    .map(|w| {
                        fedless::store::OutageWindow::parse(w.trim()).ok_or_else(|| {
                            format!("bad --outage window {w:?} (<start_s>:<dur_s>)")
                        })
                    })
                    .collect::<Result<Vec<_>, _>>()?;
            }
            "--sync-quorum" => {
                cfg.sync_quorum = value
                    .parse()
                    .map_err(|_| format!("bad --sync-quorum {value:?} (fraction in (0, 1])"))?;
            }
            "--scale" => {
                scale = Scale::parse(value).ok_or_else(|| format!("bad --scale {value:?}"))?;
            }
            "--seed" => {
                cfg.seed = value.parse().map_err(|_| format!("bad --seed {value:?}"))?;
            }
            other => return Err(format!("unknown run flag {other:?}")),
        }
        i += 1;
    }
    // re-resolve the preset for the chosen model/scale, keeping overrides
    let chosen = base_cfg(&model, scale);
    cfg.model = chosen.model;
    cfg.epochs = chosen.epochs;
    cfg.steps_per_epoch = chosen.steps_per_epoch;
    cfg.train_size = chosen.train_size;
    cfg.test_size = chosen.test_size;
    if cfg.trace && cfg.log_dir.is_none() {
        // traced runs need somewhere to put the exports
        cfg.log_dir = Some("runs".into());
    }
    if synthetic {
        // the synthetic path is always simulated time (no PJRT, no
        // datasets) — protocol + store + clock only
        cfg.clock = ClockKind::Virtual;
    }
    cfg.validate().map_err(|e| format!("{e:#}"))?;
    if synthetic {
        return run_synthetic_cmd(&cfg);
    }

    eprintln!(
        "running {} (scale={}, clock={})...",
        cfg.run_name(),
        scale.name(),
        cfg.clock.name()
    );
    let res = run_experiment(&cfg).map_err(|e| format!("{e:#}"))?;
    println!("mode         : {}", cfg.mode.label());
    println!("clock        : {}", cfg.clock.name());
    println!("compress     : {}", cfg.compress.label());
    println!("threads      : {}", fedless::config::threads_label(cfg.threads));
    println!("scheduler    : {}", cfg.scheduler.name());
    println!("participation: {}", cfg.participation);
    println!(
        "availability : {}",
        if cfg.availability == fedless::config::AvailabilitySpec::None {
            "none".into()
        } else {
            cfg.availability.label()
        }
    );
    println!("strategy     : {}", cfg.strategy.label());
    println!(
        "adversary    : {}",
        cfg.adversary.map(|a| a.label()).unwrap_or_else(|| "none".into())
    );
    if cfg.fault.is_active() {
        println!(
            "fault        : p={} ({} outage window(s))",
            cfg.fault.p_fail,
            cfg.fault.outages.len()
        );
    }
    if cfg.sync_quorum < 1.0 {
        println!("sync quorum  : {}", cfg.sync_quorum);
    }
    println!("accuracy     : {:.4}", res.final_accuracy);
    println!("test loss    : {:.4}", res.final_loss);
    println!("wall clock   : {:.2}s", res.wall_clock_s);
    // digest / traffic / idle / per-node table come from the same
    // RunSummary the trace exporter writes and `inspect` reads back, so
    // the live summary and the post-hoc one can never disagree
    print!("{}", res.run_summary(&cfg.run_name()).render());
    if let Some(dir) = &res.trace_dir {
        println!("trace        : {}", dir.display());
    }
    println!("{}", res.render_timelines(72));
    Ok(())
}

/// `fedbench run --synthetic`: a traced protocol-level federation with
/// synthetic weights — no datasets, no PJRT artifacts — under either
/// scheduler. Prints the same [`fedless::trace::RunSummary`] rendering
/// as a real run and exports the same trace files.
fn run_synthetic_cmd(cfg: &ExperimentConfig) -> Result<(), String> {
    use fedless::trace::{export_run, run_synthetic, SyntheticSpec};
    let spec = SyntheticSpec::from_config(cfg);
    eprintln!(
        "running synthetic {} ({} nodes, {} epochs, scheduler={})...",
        cfg.run_name(),
        cfg.n_nodes,
        cfg.epochs,
        cfg.scheduler.name()
    );
    let run = run_synthetic(&spec).map_err(|e| format!("{e:#}"))?;
    let pool = fedless::par::ChunkPool::from_config(cfg.threads);
    let summary = run
        .summary(&cfg.run_name(), cfg.epochs as u64, pool)
        .map_err(|e| format!("{e:#}"))?;
    let timelines: Vec<&fedless::metrics::Timeline> = run.timelines.iter().collect();
    if cfg.trace {
        let dir = cfg
            .log_dir
            .clone()
            .unwrap_or_else(|| "runs".into())
            .join(cfg.run_name());
        let path = export_run(&dir, &run.tracer, &timelines, &summary)
            .map_err(|e| format!("{e:#}"))?;
        println!("trace        : {}", path.display());
    }
    print!("{}", summary.render());
    println!("{}", fedless::metrics::timeline::render_ascii(&timelines, 72));
    Ok(())
}

/// `fedbench inspect <run-dir>`: load a traced run's `analysis.json`
/// and print its per-round divergence tables and per-node span shares —
/// the post-hoc twin of the `fedbench run` summary.
fn cmd_inspect(args: &[String]) -> Result<(), String> {
    let dir = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or("usage: fedbench inspect <run-dir>")?;
    let summary = fedless::trace::load_summary(std::path::Path::new(dir))
        .map_err(|e| format!("{e:#}"))?;
    println!("run          : {}", summary.run_name);
    println!("nodes        : {}", summary.n_nodes);
    println!("wall clock   : {:.2}s", summary.wall_clock_s);
    print!("{}", summary.render());
    Ok(())
}

/// `fedbench sweep SPEC.json [--jobs N] [--out FILE] [--csv FILE]` — run a
/// JSON-defined experiment grid on the bounded sweep scheduler and print
/// the aggregated mean ± std table.
fn cmd_sweep(args: &[String]) -> Result<(), String> {
    let mut spec_path: Option<&str> = None;
    let mut jobs: Option<usize> = None;
    let mut out: Option<&str> = None;
    let mut csv: Option<&str> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--jobs" => {
                i += 1;
                let v = args.get(i).ok_or("--jobs needs a value")?;
                jobs = Some(v.parse().map_err(|_| format!("bad --jobs {v:?}"))?);
            }
            "--out" => {
                i += 1;
                out = Some(args.get(i).map(String::as_str).ok_or("--out needs a value")?);
            }
            "--csv" => {
                i += 1;
                csv = Some(args.get(i).map(String::as_str).ok_or("--csv needs a value")?);
            }
            other if spec_path.is_none() && !other.starts_with("--") => {
                spec_path = Some(other);
            }
            other => return Err(format!("unknown sweep flag {other:?}")),
        }
        i += 1;
    }
    let spec_path =
        spec_path.ok_or("usage: fedbench sweep SPEC.json [--jobs N] [--out FILE] [--csv FILE]")?;
    let text = std::fs::read_to_string(spec_path)
        .map_err(|e| format!("reading {spec_path:?}: {e}"))?;
    let mut spec = SweepSpec::parse_json(&text).map_err(|e| format!("{e:#}"))?;
    if let Some(j) = jobs {
        spec.jobs = j;
    }
    eprintln!(
        "sweep: {} cell(s) x {} seed(s) = {} trial(s)",
        spec.cells().len(),
        spec.seeds.len(),
        spec.n_trials()
    );
    let report = run_sweep(&spec).map_err(|e| format!("{e:#}"))?;
    println!("{}", report.to_markdown());
    if let Some(path) = out {
        std::fs::write(path, report.to_markdown()).map_err(|e| format!("write {path:?}: {e}"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = csv {
        std::fs::write(path, report.to_csv()).map_err(|e| format!("write {path:?}: {e}"))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprintln!(
            "usage: fedbench <table1..table7|fig1|robustness|all> \
             [--scale smoke|small|paper] [--trials N] [--seed S] [--out FILE] \
             [--virtual-clock]\n\
             \x20      fedbench run [--mode sync|async|local|gossip[:m]] [--model M] \
             [--nodes N] [--skew S] [--strategy S] [--scale S] [--seed S] \
             [--compress none|q8|topk:<f>|delta-q8] [--threads auto|N] \
             [--robust median|trimmed-mean[:f]|krum[:f]|trust-weighted] \
             [--adversary none|byzantine[:k]|scale[:f]|signflip[:k]|stale[:r]] \
             [--scheduler threads|events] [--participation F] \
             [--availability none|churn:<p>|diurnal:<period>|stragglers:<frac>:<mult>] \
             [--fault P] [--outage <start_s>:<dur_s>[,...]] [--sync-quorum F] \
             [--virtual-clock] [--trace|--no-trace] [--synthetic]\n\
             \x20      fedbench inspect <run-dir>\n\
             \x20      fedbench sweep SPEC.json [--jobs N] [--out FILE] [--csv FILE]"
        );
        std::process::exit(2);
    };
    if cmd == "run" {
        if let Err(e) = cmd_run(&args[1..]) {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        return;
    }
    if cmd == "inspect" {
        if let Err(e) = cmd_inspect(&args[1..]) {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        return;
    }
    if cmd == "sweep" {
        if let Err(e) = cmd_sweep(&args[1..]) {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        return;
    }
    let mut o = Opts {
        scale: Scale::Small,
        trials: None,
        out: None,
        seed: 42,
        clock: ClockKind::Real,
    };
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--virtual-clock" => {
                o.clock = ClockKind::Virtual;
            }
            "--scale" => {
                i += 1;
                o.scale = Scale::parse(&args[i]).unwrap_or_else(|| {
                    eprintln!("bad scale {:?}", args[i]);
                    std::process::exit(2);
                });
            }
            "--trials" => {
                i += 1;
                o.trials = Some(args[i].parse().expect("bad trials"));
            }
            "--seed" => {
                i += 1;
                o.seed = args[i].parse().expect("bad seed");
            }
            "--out" => {
                i += 1;
                o.out = Some(args[i].clone());
            }
            other => {
                eprintln!("unknown flag {other}");
                std::process::exit(2);
            }
        }
        i += 1;
    }

    let names: Vec<&str> = if cmd == "all" {
        vec!["table1", "table2", "table3", "table4", "table5", "table6", "table7", "fig1", "robustness"]
    } else {
        vec![cmd.as_str()]
    };

    let mut all_text = String::new();
    for name in names {
        match run_one(name, &o) {
            Some(t) => all_text.push_str(&t.text),
            None => {
                eprintln!("unknown experiment {name:?}");
                std::process::exit(2);
            }
        }
    }
    if let Some(path) = &o.out {
        std::fs::write(path, &all_text).expect("write --out file");
        eprintln!("wrote {path}");
    }
}
