//! Sweep orchestration: run a *grid* of experiments — the unit at which
//! the paper argues (§4) — in one call.
//!
//! The paper's evidence is never a single trial: every table is a
//! cartesian grid (sync vs async × strategy × skew × node count, several
//! seeds per cell) and every claim is a *shape* across that grid. This
//! module makes the grid the first-class object:
//!
//! * [`SweepSpec`] — the grid definition: a base
//!   [`crate::config::ExperimentConfig`] plus axes, parseable from JSON
//!   (`fedbench sweep spec.json`) or built programmatically;
//! * [`run_sweep`] — a work-stealing scheduler that runs the expanded
//!   trials on a bounded worker pool, each trial fully isolated (own
//!   seed, own data shards, own store namespace);
//! * [`SweepReport`] — per-cell mean ± std aggregation rendered as a
//!   paper-style Markdown table or CSV.
//!
//! # Example
//!
//! ```no_run
//! use fedless::sweep::{run_sweep, SweepSpec};
//!
//! let spec = SweepSpec::parse_json(
//!     r#"{
//!         "model": "mnist",
//!         "modes": ["sync", "async"],
//!         "strategies": ["fedavg", "fedavgm"],
//!         "skews": [0.0, 0.9],
//!         "n_nodes": 2,
//!         "trials": 2,
//!         "epochs": 2,
//!         "steps_per_epoch": 25,
//!         "store": "sharded",
//!         "jobs": 4
//!     }"#,
//! )
//! .unwrap();
//! let report = run_sweep(&spec).unwrap();
//! println!("{}", report.to_markdown());
//! ```

pub mod report;
pub mod scheduler;
pub mod spec;

pub use report::{CellSummary, SweepReport, TrialMetrics, TrialOutcome};
pub use scheduler::{default_jobs, run_sweep, run_sweep_with};
pub use spec::{CellKey, SweepSpec, SweepTrial};
